//! Mid-tread uniform quantizer: q = round(x / d), x̂ = q * d.
//! Reconstruction error is bounded by d/2 per value.

/// Uniform quantizer with bin width `d`.
#[derive(Clone, Copy, Debug)]
pub struct UniformQuantizer {
    pub bin: f64,
}

impl UniformQuantizer {
    pub fn new(bin: f64) -> Self {
        assert!(bin > 0.0 && bin.is_finite(), "bin width must be positive");
        Self { bin }
    }

    /// Pick the bin width so the *per-value* max error is `eps`.
    pub fn for_max_error(eps: f64) -> Self {
        Self::new(2.0 * eps)
    }

    #[inline]
    pub fn quantize(&self, x: f64) -> i64 {
        (x / self.bin).round() as i64
    }

    #[inline]
    pub fn dequantize(&self, q: i64) -> f64 {
        q as f64 * self.bin
    }

    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<i64> {
        xs.iter().map(|&x| self.quantize(x as f64)).collect()
    }

    pub fn dequantize_slice(&self, qs: &[i64]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize(q) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn error_bounded_by_half_bin() {
        let q = UniformQuantizer::new(0.01);
        let mut rng = Prng::new(1);
        for _ in 0..10_000 {
            let x = rng.uniform(-5.0, 5.0);
            let xh = q.dequantize(q.quantize(x));
            assert!((x - xh).abs() <= 0.005 + 1e-12);
        }
    }

    #[test]
    fn zero_maps_to_zero() {
        let q = UniformQuantizer::new(0.1);
        assert_eq!(q.quantize(0.0), 0);
        assert_eq!(q.dequantize(0), 0.0);
    }

    #[test]
    fn for_max_error_honors_bound() {
        let q = UniformQuantizer::for_max_error(1e-3);
        let mut rng = Prng::new(2);
        for _ in 0..5_000 {
            let x = rng.uniform(-1.0, 1.0);
            assert!((x - q.dequantize(q.quantize(x))).abs() <= 1e-3 + 1e-15);
        }
    }

    #[derive(Clone, Debug)]
    struct QCase {
        bin: f64,
        xs: Vec<f32>,
    }

    impl Arbitrary for QCase {
        fn generate(rng: &mut Prng) -> Self {
            let bin = 10f64.powf(rng.uniform(-6.0, 0.0));
            let n = 1 + rng.index(64);
            let scale = 10f64.powf(rng.uniform(-6.0, 2.0));
            QCase {
                bin,
                xs: (0..n).map(|_| (rng.normal() * scale) as f32).collect(),
            }
        }
        fn shrink(&self) -> Vec<Self> {
            if self.xs.len() > 1 {
                vec![QCase {
                    bin: self.bin,
                    xs: self.xs[..self.xs.len() / 2].to_vec(),
                }]
            } else {
                vec![]
            }
        }
    }

    #[test]
    fn prop_roundtrip_error_bound() {
        check::<QCase, _>(42, 300, |c| {
            let q = UniformQuantizer::new(c.bin);
            let qs = q.quantize_slice(&c.xs);
            let xh = q.dequantize_slice(&qs);
            c.xs.iter().zip(&xh).all(|(a, b)| {
                let tol = c.bin / 2.0 + (*a as f64).abs() * 1e-6 + 1e-12;
                ((*a as f64) - (*b as f64)).abs() <= tol
            })
        });
    }
}
