//! LEB128 varints + zigzag mapping for signed quantized symbols.

use crate::error::{Error, Result};

/// Map signed to unsigned interleaving: 0,-1,1,-2,2 -> 0,1,2,3,4.
#[inline]
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
pub fn zigzag_decode(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Append a LEB128 varint.
pub fn write_varint(buf: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            buf.push(byte);
            return;
        }
        buf.push(byte | 0x80);
    }
}

/// Read a LEB128 varint from `buf[*pos..]`, advancing `pos`.
pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *buf
            .get(*pos)
            .ok_or_else(|| Error::codec("varint: unexpected EOF"))?;
        *pos += 1;
        v |= ((byte & 0x7F) as u64) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            return Err(Error::codec("varint: overflow"));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn zigzag_roundtrip_edges() {
        for v in [0i64, 1, -1, i64::MAX, i64::MIN, 12345, -98765] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
    }

    #[test]
    fn varint_roundtrip() {
        let mut rng = Prng::new(1);
        let vals: Vec<u64> = (0..2000)
            .map(|i| {
                if i % 3 == 0 {
                    rng.next_u64()
                } else {
                    rng.next_u64() % 300
                }
            })
            .collect();
        let mut buf = Vec::new();
        for &v in &vals {
            write_varint(&mut buf, v);
        }
        let mut pos = 0;
        for &v in &vals {
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v);
        }
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn varint_eof_is_error() {
        let buf = [0x80u8]; // continuation bit but no next byte
        let mut pos = 0;
        assert!(read_varint(&buf, &mut pos).is_err());
    }
}
