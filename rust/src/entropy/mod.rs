//! Entropy coding: canonical Huffman (the paper's coder for quantized AE
//! latents and PCA coefficients) plus varint/zigzag stream helpers and a
//! self-contained integer codec (`IntCodec`) that serializes its own
//! dictionary — "all the dictionaries for entropy coding" are counted in
//! the compressed-output accounting, as in the paper.

pub mod huffman;
pub mod stream;

pub use huffman::{Huffman, IntCodec};
pub use stream::{read_varint, write_varint, zigzag_decode, zigzag_encode};
