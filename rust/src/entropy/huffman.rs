//! Canonical Huffman coder.
//!
//! `Huffman` codes a dense alphabet `0..n` from symbol counts; codes are
//! canonical so only the code *lengths* need to be serialized.  `IntCodec`
//! wraps it for arbitrary `i64` symbol streams (quantized latents, PCA
//! coefficients, SZ quantization bins): it builds the dictionary, encodes
//! it (zigzag varints + lengths), and decodes without external state.

use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::entropy::stream::{read_varint, write_varint, zigzag_decode, zigzag_encode};
use crate::error::{Error, Result};
use crate::util::{BitReader, BitWriter};

/// Maximum code length we allow (bit-writer limit is 57).
const MAX_LEN: u32 = 48;

/// Canonical Huffman code over a dense alphabet.
#[derive(Clone, Debug)]
pub struct Huffman {
    /// Code length per symbol (0 = symbol absent).
    pub lens: Vec<u32>,
    /// Canonical code per symbol (MSB-first).
    pub codes: Vec<u64>,
    // canonical decode tables, indexed by length l in 1..=max_len
    count: Vec<u64>,       // #codes of length l
    first_code: Vec<u64>,  // canonical first code of length l
    first_index: Vec<usize>, // index into sorted_symbols of first len-l symbol
    sorted_symbols: Vec<u32>,
    max_len: u32,
}

impl Huffman {
    /// Build from symbol counts (length = alphabet size, counts may be 0).
    pub fn from_counts(counts: &[u64]) -> Result<Huffman> {
        let n = counts.len();
        if n == 0 {
            return Err(Error::codec("huffman: empty alphabet"));
        }
        let mut lens = vec![0u32; n];
        let present: Vec<usize> = (0..n).filter(|&i| counts[i] > 0).collect();
        match present.len() {
            0 => return Err(Error::codec("huffman: all counts zero")),
            1 => lens[present[0]] = 1,
            _ => {
                build_lengths(counts, &mut lens)?;
                if lens.iter().any(|&l| l > MAX_LEN) {
                    // Flatten the distribution to bound depth, rebuild.
                    let total: u64 = counts.iter().sum();
                    let floor = (total >> 40).max(1);
                    let clamped: Vec<u64> = counts
                        .iter()
                        .map(|&c| if c > 0 { c.max(floor) } else { 0 })
                        .collect();
                    lens.iter_mut().for_each(|l| *l = 0);
                    build_lengths(&clamped, &mut lens)?;
                    if lens.iter().any(|&l| l > MAX_LEN) {
                        return Err(Error::codec("huffman: depth overflow"));
                    }
                }
            }
        }
        Self::from_lens(lens)
    }

    /// Reconstruct canonical codes from lengths alone (decoder path).
    pub fn from_lens(lens: Vec<u32>) -> Result<Huffman> {
        let max_len = lens.iter().cloned().max().unwrap_or(0);
        if max_len == 0 {
            return Err(Error::codec("huffman: no symbols"));
        }
        if max_len > MAX_LEN {
            return Err(Error::codec("huffman: length overflow"));
        }
        // canonical ordering: by (length, symbol)
        let mut sorted: Vec<u32> =
            (0..lens.len() as u32).filter(|&s| lens[s as usize] > 0).collect();
        sorted.sort_by_key(|&s| (lens[s as usize], s));

        let mut count = vec![0u64; (max_len + 1) as usize];
        for &s in &sorted {
            count[lens[s as usize] as usize] += 1;
        }
        // Kraft check: sum count[l] * 2^(max_len - l) must fit the code space
        let mut kraft: u128 = 0;
        for l in 1..=max_len {
            kraft += (count[l as usize] as u128) << (max_len - l);
        }
        if kraft > 1u128 << max_len {
            return Err(Error::codec("huffman: invalid lengths (kraft > 1)"));
        }

        let mut first_code = vec![0u64; (max_len + 1) as usize];
        let mut first_index = vec![0usize; (max_len + 1) as usize];
        let mut code = 0u64;
        let mut idx = 0usize;
        for l in 1..=max_len as usize {
            first_code[l] = code;
            first_index[l] = idx;
            code = (code + count[l]) << 1;
            idx += count[l] as usize;
        }

        let mut codes = vec![0u64; lens.len()];
        let mut next = first_code.clone();
        for &s in &sorted {
            let l = lens[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }
        Ok(Huffman {
            lens,
            codes,
            count,
            first_code,
            first_index,
            sorted_symbols: sorted,
            max_len,
        })
    }

    /// Encode one symbol (MSB-first canonical code).
    #[inline]
    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u32) {
        let l = self.lens[sym as usize];
        debug_assert!(l > 0, "encoding absent symbol {sym}");
        let code = self.codes[sym as usize];
        // emit MSB-first so canonical decode works
        for i in (0..l).rev() {
            w.write_bit((code >> i) & 1 == 1);
        }
    }

    /// Decode one symbol (canonical table walk, O(code length)).
    #[inline]
    pub fn decode_symbol(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u64;
        let mut l = 0usize;
        loop {
            let bit = r
                .read_bit()
                .ok_or_else(|| Error::codec("huffman: EOF mid-symbol"))?;
            code = (code << 1) | bit as u64;
            l += 1;
            if l > self.max_len as usize {
                return Err(Error::codec("huffman: bad code"));
            }
            let c = self.count[l];
            if c > 0 {
                let fc = self.first_code[l];
                if code >= fc && code < fc + c {
                    return Ok(self.sorted_symbols[self.first_index[l] + (code - fc) as usize]);
                }
            }
        }
    }

    /// Mean code length in bits under the given counts (for diagnostics).
    pub fn mean_bits(&self, counts: &[u64]) -> f64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        counts
            .iter()
            .enumerate()
            .map(|(s, &c)| c as f64 * self.lens[s] as f64)
            .sum::<f64>()
            / total as f64
    }
}

/// Heap-based Huffman code-length computation.
fn build_lengths(counts: &[u64], lens: &mut [u32]) -> Result<()> {
    #[derive(PartialEq, Eq)]
    struct Node {
        weight: u64,
        id: usize,
    }
    impl Ord for Node {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            other
                .weight
                .cmp(&self.weight)
                .then(other.id.cmp(&self.id)) // min-heap, deterministic
        }
    }
    impl PartialOrd for Node {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let present: Vec<usize> = (0..counts.len()).filter(|&i| counts[i] > 0).collect();
    let mut parent: Vec<usize> = vec![usize::MAX; present.len()];
    let mut heap = BinaryHeap::new();
    for (leaf_id, &sym) in present.iter().enumerate() {
        heap.push(Node {
            weight: counts[sym],
            id: leaf_id,
        });
    }
    // internal nodes get ids >= present.len()
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        let id = parent.len();
        parent.push(usize::MAX);
        parent[a.id] = id;
        parent[b.id] = id;
        heap.push(Node {
            weight: a.weight.saturating_add(b.weight),
            id,
        });
    }
    for (leaf_id, &sym) in present.iter().enumerate() {
        let mut l = 0u32;
        let mut p = parent[leaf_id];
        while p != usize::MAX {
            l += 1;
            p = parent[p];
        }
        lens[sym] = l.max(1);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// IntCodec: self-describing i64 stream codec
// ---------------------------------------------------------------------------

/// Self-contained codec for `i64` symbol streams.  The output embeds the
/// dictionary: `[n_alphabet][zigzag-varint symbols][varint lens][n_values]
/// [bitstream]`.
pub struct IntCodec;

impl IntCodec {
    pub fn encode(values: &[i64]) -> Result<Vec<u8>> {
        let mut alphabet: Vec<i64> = Vec::new();
        let mut counts_map: HashMap<i64, u64> = HashMap::new();
        for &v in values {
            *counts_map.entry(v).or_insert(0) += 1;
        }
        alphabet.extend(counts_map.keys());
        alphabet.sort_unstable();
        let index: HashMap<i64, u32> = alphabet
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        let counts: Vec<u64> = alphabet.iter().map(|v| counts_map[v]).collect();

        let mut out = Vec::new();
        write_varint(&mut out, alphabet.len() as u64);
        // delta-coded sorted alphabet for compactness
        let mut prev = 0i64;
        for &v in &alphabet {
            write_varint(&mut out, zigzag_encode(v.wrapping_sub(prev)));
            prev = v;
        }
        write_varint(&mut out, values.len() as u64);
        if values.is_empty() {
            return Ok(out);
        }
        if alphabet.len() == 1 {
            return Ok(out); // stream fully determined by the dictionary
        }
        let huff = Huffman::from_counts(&counts)?;
        for &l in &huff.lens {
            write_varint(&mut out, l as u64);
        }
        let mut w = BitWriter::new();
        for &v in values {
            huff.encode_symbol(&mut w, index[&v]);
        }
        let bits = w.finish();
        write_varint(&mut out, bits.len() as u64);
        out.extend_from_slice(&bits);
        Ok(out)
    }

    pub fn decode(buf: &[u8]) -> Result<Vec<i64>> {
        let mut pos = 0;
        let n_alpha = read_varint(buf, &mut pos)? as usize;
        let mut alphabet = Vec::with_capacity(n_alpha);
        let mut prev = 0i64;
        for _ in 0..n_alpha {
            prev = prev.wrapping_add(zigzag_decode(read_varint(buf, &mut pos)?));
            alphabet.push(prev);
        }
        let n_values = read_varint(buf, &mut pos)? as usize;
        if n_values == 0 {
            return Ok(Vec::new());
        }
        if n_alpha == 0 {
            return Err(Error::codec("intcodec: values but empty alphabet"));
        }
        if n_alpha == 1 {
            return Ok(vec![alphabet[0]; n_values]);
        }
        let mut lens = Vec::with_capacity(n_alpha);
        for _ in 0..n_alpha {
            lens.push(read_varint(buf, &mut pos)? as u32);
        }
        let huff = Huffman::from_lens(lens)?;
        let nbits = read_varint(buf, &mut pos)? as usize;
        let bits = buf
            .get(pos..pos + nbits)
            .ok_or_else(|| Error::codec("intcodec: truncated bitstream"))?;
        let mut r = BitReader::new(bits);
        let mut out = Vec::with_capacity(n_values);
        for _ in 0..n_values {
            out.push(alphabet[huff.decode_symbol(&mut r)? as usize]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn roundtrip_simple() {
        let vals = vec![0i64, 0, 0, 1, -1, 2, 0, 0, 5, 0];
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let vals = vec![42i64; 1000];
        let enc = IntCodec::encode(&vals).unwrap();
        assert!(enc.len() < 32, "degenerate stream should be tiny: {}", enc.len());
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[test]
    fn roundtrip_empty() {
        let enc = IntCodec::encode(&[]).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), Vec::<i64>::new());
    }

    #[test]
    fn skewed_distribution_compresses() {
        // geometric-ish: mostly zeros — typical quantized residuals
        let mut rng = Prng::new(3);
        let vals: Vec<i64> = (0..50_000)
            .map(|_| {
                let u = rng.next_f64();
                if u < 0.85 {
                    0
                } else if u < 0.95 {
                    (rng.index(3) as i64) - 1
                } else {
                    (rng.index(64) as i64) - 32
                }
            })
            .collect();
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
        // entropy ~< 1.2 bits/val here; assert well under 2 bytes/val
        assert!(
            enc.len() < vals.len() / 4,
            "poor compression: {} bytes for {} values",
            enc.len(),
            vals.len()
        );
    }

    #[test]
    fn extreme_values() {
        let vals = vec![i64::MAX, i64::MIN, 0, i64::MAX, -1, 1];
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[derive(Clone, Debug)]
    struct Stream(Vec<i64>);
    impl Arbitrary for Stream {
        fn generate(rng: &mut Prng) -> Self {
            let n = rng.index(500);
            let spread = 1 + rng.index(1000) as i64;
            Stream(
                (0..n)
                    .map(|_| (rng.normal() * spread as f64) as i64)
                    .collect(),
            )
        }
        fn shrink(&self) -> Vec<Self> {
            if self.0.len() > 1 {
                vec![
                    Stream(self.0[..self.0.len() / 2].to_vec()),
                    Stream(self.0[self.0.len() / 2..].to_vec()),
                ]
            } else {
                vec![]
            }
        }
    }

    #[test]
    fn prop_roundtrip() {
        check::<Stream, _>(7, 200, |s| {
            let enc = IntCodec::encode(&s.0).unwrap();
            IntCodec::decode(&enc).unwrap() == s.0
        });
    }

    #[test]
    fn truncated_stream_is_error_not_panic() {
        let vals: Vec<i64> = (0..100).map(|i| i % 7).collect();
        let enc = IntCodec::encode(&vals).unwrap();
        for cut in [1usize, enc.len() / 2, enc.len() - 1] {
            let r = IntCodec::decode(&enc[..cut]);
            assert!(r.is_err() || r.unwrap() != vals);
        }
    }
}
