//! Canonical Huffman coder.
//!
//! `Huffman` codes a dense alphabet `0..n` from symbol counts; codes are
//! canonical so only the code *lengths* need to be serialized.  `IntCodec`
//! wraps it for arbitrary `i64` symbol streams (quantized latents, PCA
//! coefficients, SZ quantization bins): it builds the dictionary, encodes
//! it (zigzag varints + lengths), and decodes without external state.
//!
//! Decoding is table-driven: the next [`TABLE_BITS`] stream bits index a
//! prefix-lookup table holding `(symbol, length)` for every code short
//! enough to fit, so the common case is one peek + one skip.  Codes longer
//! than the table (rare tails of very skewed alphabets) fall back to the
//! canonical bit-at-a-time walk, which is also the reference
//! implementation the property tests compare against.  Encoding emits the
//! bit-reversed canonical code with a single accumulator push instead of
//! one call per bit.  Both directions produce/consume bit streams
//! identical to the pre-table coder, so archive bytes are unchanged.

use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::entropy::stream::{read_varint, write_varint, zigzag_decode, zigzag_encode};
use crate::error::{Error, Result};
use crate::util::{BitReader, BitWriter};

/// Maximum code length we allow (bit-writer limit is 57).
const MAX_LEN: u32 = 48;

/// Width of the prefix-lookup decode table (4096 entries, 16 KiB).
const TABLE_BITS: u32 = 12;

/// Canonical Huffman code over a dense alphabet.
#[derive(Clone, Debug)]
pub struct Huffman {
    /// Code length per symbol (0 = symbol absent).
    pub lens: Vec<u32>,
    /// Canonical code per symbol (MSB-first).
    pub codes: Vec<u64>,
    /// Canonical code bit-reversed into the LSB-first stream order — one
    /// `BitWriter::write` emits the same bits the MSB-first per-bit loop
    /// used to.
    codes_rev: Vec<u64>,
    // canonical decode tables, indexed by length l in 1..=max_len
    count: Vec<u64>,       // #codes of length l
    first_code: Vec<u64>,  // canonical first code of length l
    first_index: Vec<usize>, // index into sorted_symbols of first len-l symbol
    sorted_symbols: Vec<u32>,
    max_len: u32,
    /// Prefix-lookup decode table indexed by the next `table_bits` stream
    /// bits (LSB-first): entry = `sym << 8 | len`; 0 marks a code longer
    /// than the table (slow path).  Empty when the alphabet is too wide
    /// to pack (never in practice).
    table: Vec<u32>,
    table_bits: u32,
}

impl Huffman {
    /// Build from symbol counts (length = alphabet size, counts may be 0).
    pub fn from_counts(counts: &[u64]) -> Result<Huffman> {
        let n = counts.len();
        if n == 0 {
            return Err(Error::codec("huffman: empty alphabet"));
        }
        let mut lens = vec![0u32; n];
        let present: Vec<usize> = (0..n).filter(|&i| counts[i] > 0).collect();
        match present.len() {
            0 => return Err(Error::codec("huffman: all counts zero")),
            1 => lens[present[0]] = 1,
            _ => {
                build_lengths(counts, &mut lens)?;
                if lens.iter().any(|&l| l > MAX_LEN) {
                    // Flatten the distribution to bound depth, rebuild.
                    let total: u64 = counts.iter().sum();
                    let floor = (total >> 40).max(1);
                    let clamped: Vec<u64> = counts
                        .iter()
                        .map(|&c| if c > 0 { c.max(floor) } else { 0 })
                        .collect();
                    lens.iter_mut().for_each(|l| *l = 0);
                    build_lengths(&clamped, &mut lens)?;
                    if lens.iter().any(|&l| l > MAX_LEN) {
                        return Err(Error::codec("huffman: depth overflow"));
                    }
                }
            }
        }
        Self::from_lens(lens)
    }

    /// Reconstruct canonical codes from lengths alone (decoder path).
    pub fn from_lens(lens: Vec<u32>) -> Result<Huffman> {
        let max_len = lens.iter().cloned().max().unwrap_or(0);
        if max_len == 0 {
            return Err(Error::codec("huffman: no symbols"));
        }
        if max_len > MAX_LEN {
            return Err(Error::codec("huffman: length overflow"));
        }
        // canonical ordering: by (length, symbol)
        let mut sorted: Vec<u32> =
            (0..lens.len() as u32).filter(|&s| lens[s as usize] > 0).collect();
        sorted.sort_by_key(|&s| (lens[s as usize], s));

        let mut count = vec![0u64; (max_len + 1) as usize];
        for &s in &sorted {
            count[lens[s as usize] as usize] += 1;
        }
        // Kraft check: sum count[l] * 2^(max_len - l) must fit the code space
        let mut kraft: u128 = 0;
        for l in 1..=max_len {
            kraft += (count[l as usize] as u128) << (max_len - l);
        }
        if kraft > 1u128 << max_len {
            return Err(Error::codec("huffman: invalid lengths (kraft > 1)"));
        }

        let mut first_code = vec![0u64; (max_len + 1) as usize];
        let mut first_index = vec![0usize; (max_len + 1) as usize];
        let mut code = 0u64;
        let mut idx = 0usize;
        for l in 1..=max_len as usize {
            first_code[l] = code;
            first_index[l] = idx;
            code = (code + count[l]) << 1;
            idx += count[l] as usize;
        }

        let mut codes = vec![0u64; lens.len()];
        let mut next = first_code.clone();
        for &s in &sorted {
            let l = lens[s as usize] as usize;
            codes[s as usize] = next[l];
            next[l] += 1;
        }

        // bit-reversed codes: the stream stores the MSB-first code at
        // ascending bit positions, which is exactly the l-bit reversal
        let mut codes_rev = vec![0u64; lens.len()];
        for &s in &sorted {
            let l = lens[s as usize];
            codes_rev[s as usize] = codes[s as usize].reverse_bits() >> (64 - l);
        }

        // prefix-lookup table: for a code of length l <= table_bits, every
        // peeked value whose low l bits equal the reversed code decodes to
        // that symbol — fill all 2^(table_bits - l) such slots
        let mut table_bits = max_len.min(TABLE_BITS);
        let table = if (lens.len() as u64) < (1u64 << 24) {
            let mut t = vec![0u32; 1usize << table_bits];
            for &s in &sorted {
                let l = lens[s as usize];
                if l > table_bits {
                    continue;
                }
                let entry = (s << 8) | l;
                let step = 1usize << l;
                let mut slot = codes_rev[s as usize] as usize;
                while slot < t.len() {
                    t[slot] = entry;
                    slot += step;
                }
            }
            t
        } else {
            // symbols would not fit in sym << 8 — decode via the walk only
            table_bits = 0;
            Vec::new()
        };

        Ok(Huffman {
            lens,
            codes,
            codes_rev,
            count,
            first_code,
            first_index,
            sorted_symbols: sorted,
            max_len,
            table,
            table_bits,
        })
    }

    /// Encode one symbol (MSB-first canonical code) as a single
    /// accumulator push of its bit-reversed form — the emitted bit stream
    /// is identical to writing the code bit by bit.
    #[inline]
    pub fn encode_symbol(&self, w: &mut BitWriter, sym: u32) {
        let l = self.lens[sym as usize];
        debug_assert!(l > 0, "encoding absent symbol {sym}");
        w.write(self.codes_rev[sym as usize], l);
    }

    /// Decode one symbol: a single prefix-table lookup for codes up to
    /// `table_bits` long (the common case), the canonical walk for longer
    /// codes and stream-end handling.
    #[inline]
    pub fn decode_symbol(&self, r: &mut BitReader) -> Result<u32> {
        if self.table_bits > 0 {
            let e = self.table[r.peek(self.table_bits) as usize];
            let l = e & 0xFF;
            if e != 0 && l as usize <= r.remaining() {
                r.skip(l);
                return Ok(e >> 8);
            }
            // e == 0: the prefix belongs to a code longer than the table
            // (or to no code at all); l > remaining: the stream ends
            // mid-symbol.  The exact walk below resolves both, erroring
            // where the pre-table decoder did.
        }
        self.decode_symbol_walk(r)
    }

    /// Canonical bit-at-a-time decode — the pre-table reference
    /// implementation, kept as the slow path for codes longer than
    /// `table_bits` and as the oracle the property tests compare the
    /// table-driven decoder against.
    pub fn decode_symbol_walk(&self, r: &mut BitReader) -> Result<u32> {
        let mut code = 0u64;
        let mut l = 0usize;
        loop {
            let bit = r
                .read_bit()
                .ok_or_else(|| Error::codec("huffman: EOF mid-symbol"))?;
            code = (code << 1) | bit as u64;
            l += 1;
            if l > self.max_len as usize {
                return Err(Error::codec("huffman: bad code"));
            }
            let c = self.count[l];
            if c > 0 {
                let fc = self.first_code[l];
                if code >= fc && code < fc + c {
                    return Ok(self.sorted_symbols[self.first_index[l] + (code - fc) as usize]);
                }
            }
        }
    }

    /// Decode `n` symbols into `emit` — the word-batched hot loop.
    ///
    /// Instead of one `peek` (with its refill check) per symbol, this
    /// refills the reader's accumulator once and then decodes as many
    /// table-hit symbols as the buffered bits allow, budgeting against
    /// [`BitReader::buffered`].  Position-identical to calling
    /// [`Self::decode_symbol`] `n` times — the fast route only fires when
    /// a full `table_bits` window is buffered (so the masked
    /// [`BitReader::peek_buffered`] equals what `peek` would return, and
    /// `len <= table_bits <= buffered <= remaining` forces the same
    /// branch), table misses take the identical canonical walk, and the
    /// stream tail falls back to the per-symbol decoder — so errors and
    /// symbols match exactly, which the property tests assert.
    pub fn decode_symbols<F: FnMut(u32)>(
        &self,
        r: &mut BitReader,
        n: usize,
        mut emit: F,
    ) -> Result<()> {
        if self.table_bits == 0 {
            for _ in 0..n {
                emit(self.decode_symbol_walk(r)?);
            }
            return Ok(());
        }
        let mask = (1u64 << self.table_bits) - 1;
        let mut i = 0usize;
        'refill: while i < n {
            r.fill();
            let mut avail = r.buffered();
            if avail < self.table_bits {
                // stream tail: fewer buffered bits than a table window —
                // decode_symbol handles short final codes and EOF exactly
                break;
            }
            while i < n {
                let e = self.table[(r.peek_buffered() & mask) as usize];
                let l = e & 0xFF;
                if e == 0 {
                    // code longer than the table: canonical walk, exactly
                    // decode_symbol's fallback; it moves the bit position
                    // arbitrarily, so our `avail` budget is stale — refill
                    emit(self.decode_symbol_walk(r)?);
                    i += 1;
                    continue 'refill;
                }
                r.skip(l);
                avail -= l;
                emit(e >> 8);
                i += 1;
                if avail < self.table_bits {
                    continue 'refill;
                }
            }
        }
        for _ in i..n {
            emit(self.decode_symbol(r)?);
        }
        Ok(())
    }

    /// Mean code length in bits under the given counts (for diagnostics).
    pub fn mean_bits(&self, counts: &[u64]) -> f64 {
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        counts
            .iter()
            .enumerate()
            .map(|(s, &c)| c as f64 * self.lens[s] as f64)
            .sum::<f64>()
            / total as f64
    }
}

/// Heap-based Huffman code-length computation.
fn build_lengths(counts: &[u64], lens: &mut [u32]) -> Result<()> {
    #[derive(PartialEq, Eq)]
    struct Node {
        weight: u64,
        id: usize,
    }
    impl Ord for Node {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            other
                .weight
                .cmp(&self.weight)
                .then(other.id.cmp(&self.id)) // min-heap, deterministic
        }
    }
    impl PartialOrd for Node {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let present: Vec<usize> = (0..counts.len()).filter(|&i| counts[i] > 0).collect();
    let mut parent: Vec<usize> = vec![usize::MAX; present.len()];
    let mut heap = BinaryHeap::new();
    for (leaf_id, &sym) in present.iter().enumerate() {
        heap.push(Node {
            weight: counts[sym],
            id: leaf_id,
        });
    }
    // internal nodes get ids >= present.len()
    while heap.len() > 1 {
        let a = heap.pop().unwrap();
        let b = heap.pop().unwrap();
        let id = parent.len();
        parent.push(usize::MAX);
        parent[a.id] = id;
        parent[b.id] = id;
        heap.push(Node {
            weight: a.weight.saturating_add(b.weight),
            id,
        });
    }
    for (leaf_id, &sym) in present.iter().enumerate() {
        let mut l = 0u32;
        let mut p = parent[leaf_id];
        while p != usize::MAX {
            l += 1;
            p = parent[p];
        }
        lens[sym] = l.max(1);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// IntCodec: self-describing i64 stream codec
// ---------------------------------------------------------------------------

/// Self-contained codec for `i64` symbol streams.  The output embeds the
/// dictionary: `[n_alphabet][zigzag-varint symbols][varint lens][n_values]
/// [bitstream]`.
pub struct IntCodec;

impl IntCodec {
    pub fn encode(values: &[i64]) -> Result<Vec<u8>> {
        let mut alphabet: Vec<i64> = Vec::new();
        let mut counts_map: HashMap<i64, u64> = HashMap::new();
        for &v in values {
            *counts_map.entry(v).or_insert(0) += 1;
        }
        alphabet.extend(counts_map.keys());
        alphabet.sort_unstable();
        let index: HashMap<i64, u32> = alphabet
            .iter()
            .enumerate()
            .map(|(i, &v)| (v, i as u32))
            .collect();
        let counts: Vec<u64> = alphabet.iter().map(|v| counts_map[v]).collect();

        let mut out = Vec::new();
        write_varint(&mut out, alphabet.len() as u64);
        // delta-coded sorted alphabet for compactness
        let mut prev = 0i64;
        for &v in &alphabet {
            write_varint(&mut out, zigzag_encode(v.wrapping_sub(prev)));
            prev = v;
        }
        write_varint(&mut out, values.len() as u64);
        if values.is_empty() {
            return Ok(out);
        }
        if alphabet.len() == 1 {
            return Ok(out); // stream fully determined by the dictionary
        }
        let huff = Huffman::from_counts(&counts)?;
        for &l in &huff.lens {
            write_varint(&mut out, l as u64);
        }
        let mut w = BitWriter::new();
        for &v in values {
            huff.encode_symbol(&mut w, index[&v]);
        }
        let bits = w.finish();
        write_varint(&mut out, bits.len() as u64);
        out.extend_from_slice(&bits);
        Ok(out)
    }

    pub fn decode(buf: &[u8]) -> Result<Vec<i64>> {
        let mut pos = 0;
        let n_alpha = read_varint(buf, &mut pos)? as usize;
        let mut alphabet = Vec::with_capacity(n_alpha);
        let mut prev = 0i64;
        for _ in 0..n_alpha {
            prev = prev.wrapping_add(zigzag_decode(read_varint(buf, &mut pos)?));
            alphabet.push(prev);
        }
        let n_values = read_varint(buf, &mut pos)? as usize;
        if n_values == 0 {
            return Ok(Vec::new());
        }
        if n_alpha == 0 {
            return Err(Error::codec("intcodec: values but empty alphabet"));
        }
        if n_alpha == 1 {
            return Ok(vec![alphabet[0]; n_values]);
        }
        let mut lens = Vec::with_capacity(n_alpha);
        for _ in 0..n_alpha {
            lens.push(read_varint(buf, &mut pos)? as u32);
        }
        let huff = Huffman::from_lens(lens)?;
        let nbits = read_varint(buf, &mut pos)? as usize;
        let bits = buf
            .get(pos..pos + nbits)
            .ok_or_else(|| Error::codec("intcodec: truncated bitstream"))?;
        let mut r = BitReader::new(bits);
        let mut out = Vec::with_capacity(n_values);
        huff.decode_symbols(&mut r, n_values, |s| out.push(alphabet[s as usize]))?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Arbitrary};
    use crate::util::Prng;

    #[test]
    fn roundtrip_simple() {
        let vals = vec![0i64, 0, 0, 1, -1, 2, 0, 0, 5, 0];
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[test]
    fn roundtrip_single_symbol() {
        let vals = vec![42i64; 1000];
        let enc = IntCodec::encode(&vals).unwrap();
        assert!(enc.len() < 32, "degenerate stream should be tiny: {}", enc.len());
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[test]
    fn roundtrip_empty() {
        let enc = IntCodec::encode(&[]).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), Vec::<i64>::new());
    }

    #[test]
    fn skewed_distribution_compresses() {
        // geometric-ish: mostly zeros — typical quantized residuals
        let mut rng = Prng::new(3);
        let vals: Vec<i64> = (0..50_000)
            .map(|_| {
                let u = rng.next_f64();
                if u < 0.85 {
                    0
                } else if u < 0.95 {
                    (rng.index(3) as i64) - 1
                } else {
                    (rng.index(64) as i64) - 32
                }
            })
            .collect();
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
        // entropy ~< 1.2 bits/val here; assert well under 2 bytes/val
        assert!(
            enc.len() < vals.len() / 4,
            "poor compression: {} bytes for {} values",
            enc.len(),
            vals.len()
        );
    }

    #[test]
    fn extreme_values() {
        let vals = vec![i64::MAX, i64::MIN, 0, i64::MAX, -1, 1];
        let enc = IntCodec::encode(&vals).unwrap();
        assert_eq!(IntCodec::decode(&enc).unwrap(), vals);
    }

    #[derive(Clone, Debug)]
    struct Stream(Vec<i64>);
    impl Arbitrary for Stream {
        fn generate(rng: &mut Prng) -> Self {
            let n = rng.index(500);
            let spread = 1 + rng.index(1000) as i64;
            Stream(
                (0..n)
                    .map(|_| (rng.normal() * spread as f64) as i64)
                    .collect(),
            )
        }
        fn shrink(&self) -> Vec<Self> {
            if self.0.len() > 1 {
                vec![
                    Stream(self.0[..self.0.len() / 2].to_vec()),
                    Stream(self.0[self.0.len() / 2..].to_vec()),
                ]
            } else {
                vec![]
            }
        }
    }

    #[test]
    fn prop_roundtrip() {
        check::<Stream, _>(7, 200, |s| {
            let enc = IntCodec::encode(&s.0).unwrap();
            IntCodec::decode(&enc).unwrap() == s.0
        });
    }

    #[test]
    fn truncated_stream_is_error_not_panic() {
        let vals: Vec<i64> = (0..100).map(|i| i % 7).collect();
        let enc = IntCodec::encode(&vals).unwrap();
        for cut in [1usize, enc.len() / 2, enc.len() - 1] {
            let r = IntCodec::decode(&enc[..cut]);
            assert!(r.is_err() || r.unwrap() != vals);
        }
    }

    /// Build a Huffman code from a random skewed histogram plus the
    /// symbol stream drawn from it.
    fn fuzz_code(rng: &mut Prng) -> (Huffman, Vec<u32>) {
        let n_sym = 2 + rng.index(300);
        // zipf-ish skew so both very short and very long codes appear
        let counts: Vec<u64> = (0..n_sym)
            .map(|i| {
                let base = 1u64 + (1u64 << rng.index(20).min(19)) / (i as u64 + 1);
                if rng.next_f64() < 0.1 {
                    0
                } else {
                    base
                }
            })
            .collect();
        if counts.iter().all(|&c| c == 0) {
            return fuzz_code(rng);
        }
        let huff = Huffman::from_counts(&counts).unwrap();
        let present: Vec<u32> = (0..n_sym as u32)
            .filter(|&s| huff.lens[s as usize] > 0)
            .collect();
        let stream: Vec<u32> = (0..rng.index(2000))
            .map(|_| present[rng.index(present.len())])
            .collect();
        (huff, stream)
    }

    /// The table-driven decoder must be bit-identical to the canonical
    /// walk (the pre-table implementation, kept as the slow path and the
    /// oracle here) on fuzzed symbol streams: same symbols *and* the same
    /// reader position after every symbol.
    #[test]
    fn prop_table_decode_matches_walk_oracle() {
        let mut rng = Prng::new(23);
        for case in 0..100 {
            let (huff, stream) = fuzz_code(&mut rng);
            let mut w = BitWriter::new();
            for &s in &stream {
                huff.encode_symbol(&mut w, s);
            }
            let bytes = w.finish();
            let mut fast = BitReader::new(&bytes);
            let mut walk = BitReader::new(&bytes);
            for (i, &want) in stream.iter().enumerate() {
                let a = huff.decode_symbol(&mut fast).unwrap();
                let b = huff.decode_symbol_walk(&mut walk).unwrap();
                assert_eq!(a, b, "case {case} symbol {i}: table vs walk");
                assert_eq!(a, want, "case {case} symbol {i}: wrong symbol");
                assert_eq!(
                    fast.remaining(),
                    walk.remaining(),
                    "case {case} symbol {i}: reader positions diverged"
                );
            }
        }
    }

    /// The word-batched decoder must match `n` calls of the per-symbol
    /// decoder exactly: same symbols, same reader position after the
    /// batch, and the same error behavior on truncated streams.  Fuzzed
    /// codes include deep trees (table misses mid-batch).
    #[test]
    fn prop_batched_decode_matches_per_symbol() {
        let mut rng = Prng::new(47);
        for case in 0..100 {
            let (huff, stream) = fuzz_code(&mut rng);
            let mut w = BitWriter::new();
            for &s in &stream {
                huff.encode_symbol(&mut w, s);
            }
            let bytes = w.finish();

            let mut batched = BitReader::new(&bytes);
            let mut got = Vec::with_capacity(stream.len());
            huff.decode_symbols(&mut batched, stream.len(), |s| got.push(s))
                .unwrap();
            let mut single = BitReader::new(&bytes);
            let want: Vec<u32> = (0..stream.len())
                .map(|_| huff.decode_symbol(&mut single).unwrap())
                .collect();
            assert_eq!(got, want, "case {case}: symbols diverged");
            assert_eq!(got, stream, "case {case}: wrong symbols");
            assert_eq!(
                batched.remaining(),
                single.remaining(),
                "case {case}: reader positions diverged"
            );

            // truncated stream: both decoders must fail at the same
            // symbol count
            if !bytes.is_empty() {
                let clipped = &bytes[..bytes.len() / 2];
                let mut br = BitReader::new(clipped);
                let mut n_batch = 0usize;
                let batch_err = huff
                    .decode_symbols(&mut br, stream.len(), |_| n_batch += 1)
                    .is_err();
                let mut sr = BitReader::new(clipped);
                let mut n_single = 0usize;
                let mut single_err = false;
                for _ in 0..stream.len() {
                    match huff.decode_symbol(&mut sr) {
                        Ok(_) => n_single += 1,
                        Err(_) => {
                            single_err = true;
                            break;
                        }
                    }
                }
                assert_eq!(
                    (n_batch, batch_err),
                    (n_single, single_err),
                    "case {case}: truncation behavior diverged"
                );
            }
        }
    }

    /// Deep Fibonacci-weight trees route every long code through the
    /// batch decoder's walk fallback; symbols and positions must still
    /// match the per-symbol decoder.
    #[test]
    fn batched_decode_handles_table_misses() {
        let mut counts = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for c in counts.iter_mut() {
            *c = a;
            let next = a.saturating_add(b);
            b = a;
            a = next;
        }
        let huff = Huffman::from_counts(&counts).unwrap();
        assert!(*huff.lens.iter().max().unwrap() > TABLE_BITS);
        let mut rng = Prng::new(61);
        let stream: Vec<u32> = (0..5000).map(|_| rng.index(40) as u32).collect();
        let mut w = BitWriter::new();
        for &s in &stream {
            huff.encode_symbol(&mut w, s);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        let mut got = Vec::new();
        huff.decode_symbols(&mut r, stream.len(), |s| got.push(s))
            .unwrap();
        assert_eq!(got, stream);
        assert_eq!(r.remaining(), {
            let mut s = BitReader::new(&bytes);
            for _ in 0..stream.len() {
                huff.decode_symbol(&mut s).unwrap();
            }
            s.remaining()
        });
    }

    /// The single-write encoder must emit the same bytes as the
    /// pre-overhaul MSB-first bit-by-bit loop.
    #[test]
    fn prop_single_write_encoder_is_bitwise_identical() {
        let mut rng = Prng::new(31);
        for _ in 0..50 {
            let (huff, stream) = fuzz_code(&mut rng);
            let mut fast = BitWriter::new();
            let mut slow = BitWriter::new();
            for &s in &stream {
                huff.encode_symbol(&mut fast, s);
                let l = huff.lens[s as usize];
                let code = huff.codes[s as usize];
                for i in (0..l).rev() {
                    slow.write_bit((code >> i) & 1 == 1);
                }
            }
            assert_eq!(fast.finish(), slow.finish());
        }
    }

    /// Deep trees (codes longer than the 12-bit table) exercise the slow
    /// path; Fibonacci-like weights force maximal depth.
    #[test]
    fn long_codes_roundtrip_through_slow_path() {
        let mut counts = vec![0u64; 40];
        let (mut a, mut b) = (1u64, 1u64);
        for c in counts.iter_mut() {
            *c = a;
            let next = a.saturating_add(b);
            b = a;
            a = next;
        }
        let huff = Huffman::from_counts(&counts).unwrap();
        assert!(
            *huff.lens.iter().max().unwrap() > TABLE_BITS,
            "tree not deep enough to test the slow path"
        );
        let mut rng = Prng::new(5);
        let stream: Vec<u32> = (0..5000).map(|_| rng.index(40) as u32).collect();
        let mut w = BitWriter::new();
        for &s in &stream {
            huff.encode_symbol(&mut w, s);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &want in &stream {
            assert_eq!(huff.decode_symbol(&mut r).unwrap(), want);
        }
    }

    /// Truncated and corrupted bit streams through the word-refill reader:
    /// the decoder must error (or misdecode) but never panic or read out
    /// of bounds.
    #[test]
    fn truncated_and_corrupt_bits_are_errors_not_panics() {
        let mut rng = Prng::new(57);
        let (huff, stream) = fuzz_code(&mut rng);
        if stream.is_empty() {
            return;
        }
        let mut w = BitWriter::new();
        for &s in &stream {
            huff.encode_symbol(&mut w, s);
        }
        let bytes = w.finish();
        // truncation: decoding all symbols from a clipped stream must fail
        // before producing more symbols than the bits can carry
        for cut in [0usize, 1, bytes.len() / 2] {
            let clipped = &bytes[..cut];
            let mut r = BitReader::new(clipped);
            let mut decoded = 0usize;
            while decoded < stream.len() {
                match huff.decode_symbol(&mut r) {
                    Ok(_) => decoded += 1,
                    Err(_) => break,
                }
            }
            // every symbol costs at least one bit
            assert!(
                decoded <= clipped.len() * 8,
                "decoded {decoded} symbols from {} bytes",
                clipped.len()
            );
        }
        // corruption: flip bytes, decode the full count — any outcome but
        // a panic is acceptable
        let mut corrupt = bytes.clone();
        for _ in 0..8.min(corrupt.len()) {
            let i = rng.index(corrupt.len());
            corrupt[i] ^= rng.next_u64() as u8;
        }
        let mut r = BitReader::new(&corrupt);
        for _ in 0..stream.len() {
            if huff.decode_symbol(&mut r).is_err() {
                break;
            }
        }
    }
}
