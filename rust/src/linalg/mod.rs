//! Dense linear-algebra substrate (f64): matrix container, symmetric Jacobi
//! eigensolver, and PCA on residual blocks — everything Algorithm 1 needs.
//! Hand-rolled because the offline image ships no LAPACK/ndarray; the
//! matrices involved are small (paper: 80 x 80 per species).

pub mod jacobi;
pub mod mat;
pub mod pca;

pub use jacobi::symmetric_eig;
pub use mat::Mat;
pub use pca::Pca;
