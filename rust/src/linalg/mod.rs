//! Dense linear-algebra substrate (f64): matrix container, symmetric Jacobi
//! eigensolver, and PCA on residual blocks — everything Algorithm 1 needs.
//! Hand-rolled because the offline image ships no LAPACK/ndarray; the
//! matrices involved are small (paper: 80 x 80 per species).
//!
//! Determinism invariant: every floating-point reduction in this module
//! keeps a fixed sequential order.  `Pca::fit_threads` parallelizes over
//! covariance row stripes (each entry still sums samples in row order),
//! so results are bit-identical for any thread count — the property the
//! guarantee pass and archive byte-stability tests rely on.

pub mod jacobi;
pub mod mat;
pub mod pca;

pub use jacobi::symmetric_eig;
pub use mat::Mat;
pub use pca::Pca;
