//! Row-major dense f64 matrix with the handful of ops PCA needs.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut m = Self::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self (r x k) * other (k x c) -> (r x c), cache-friendly ikj loops.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// y = self * x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// y = selfᵀ * x (no explicit transpose).
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (j, a) in self.row(i).iter().enumerate() {
                y[j] += a * xi;
            }
        }
        y
    }

    pub fn frobenius(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(&[vec![1.0, -2.0, 0.5], vec![0.0, 3.0, 9.0]]);
        let i3 = Mat::identity(3);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let x = vec![1.0, -1.0, 2.0];
        assert_eq!(a.matvec(&x), vec![5.0, 11.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }
}
