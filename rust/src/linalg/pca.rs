//! PCA over residual block vectors (Algorithm 1's basis-matrix step).
//!
//! Fits the covariance of N x D samples (D = 80 per species in the paper)
//! and eigendecomposes it with the Jacobi solver; the resulting orthonormal
//! basis U (columns sorted by descending eigenvalue) is what residuals are
//! projected onto.

use crate::linalg::{symmetric_eig, Mat};

/// A fitted PCA basis.
#[derive(Clone, Debug)]
pub struct Pca {
    /// D x D orthonormal basis; column j = j-th principal direction.
    pub basis: Mat,
    /// Descending eigenvalues (variances along each direction).
    pub eigenvalues: Vec<f64>,
    /// Sample mean (D); the paper projects raw residuals, so fitting with
    /// `centered = false` keeps the mean at zero.
    pub mean: Vec<f64>,
}

/// Sequential-path threshold: below this many multiply-adds the thread
/// spawn cost dominates and `fit_threads` runs the scalar loop.
const PAR_MIN_WORK: usize = 1 << 14;

impl Pca {
    /// Fit from `n` samples of dimension `d` stored row-major in `samples`.
    /// `centered == false` skips mean subtraction (residuals are ~zero-mean
    /// by construction and Algorithm 1 reconstructs with `U c` alone).
    pub fn fit(samples: &[f32], n: usize, d: usize, centered: bool) -> Pca {
        Self::fit_threads(samples, n, d, centered, 1)
    }

    /// Like [`Self::fit`], accumulating the covariance on up to `threads`
    /// workers (`std::thread::scope`, as the shard engine's stages do).
    ///
    /// Parallelism is over upper-triangular covariance *row stripes*
    /// (balanced by entry count), never over the sample reduction: every
    /// entry C\[i\]\[j\] is summed over samples in row order by exactly one
    /// worker, so the covariance — and therefore the eigenbasis, the
    /// certified bounds, and the archive bytes — is bit-identical to the
    /// single-threaded fit for any thread count.
    pub fn fit_threads(
        samples: &[f32],
        n: usize,
        d: usize,
        centered: bool,
        threads: usize,
    ) -> Pca {
        assert_eq!(samples.len(), n * d);
        let mut mean = vec![0.0f64; d];
        if centered && n > 0 {
            for row in samples.chunks_exact(d) {
                for (m, &v) in mean.iter_mut().zip(row) {
                    *m += v as f64;
                }
            }
            for m in mean.iter_mut() {
                *m /= n as f64;
            }
        }

        // covariance C = Σ (x-μ)(x-μ)ᵀ / n, accumulated upper-triangular
        let mut cov = Mat::zeros(d, d);
        let threads = threads.max(1).min(d.max(1));
        if threads == 1 || n * d < PAR_MIN_WORK {
            let mut xc = vec![0.0f64; d];
            for row in samples.chunks_exact(d) {
                crate::simd::center_f32_to_f64(&mut xc, row, &mean);
                for i in 0..d {
                    let xi = xc[i];
                    if xi == 0.0 {
                        continue;
                    }
                    let crow = cov.row_mut(i);
                    crate::simd::axpy_f64(&mut crow[i..], xi, &xc[i..]);
                }
            }
        } else {
            // stripe boundaries balancing Σ (d - i) per worker: row i of
            // the upper triangle holds d - i entries
            let total = d * (d + 1) / 2;
            let per = total.div_ceil(threads);
            let mut bounds = vec![0usize];
            let mut acc = 0usize;
            for i in 0..d {
                acc += d - i;
                if acc >= per && bounds.len() < threads && i + 1 < d {
                    bounds.push(i + 1);
                    acc = 0;
                }
            }
            bounds.push(d);
            // split the covariance into disjoint per-stripe row slices
            let mut stripes: Vec<&mut [f64]> = Vec::with_capacity(bounds.len() - 1);
            let mut rest: &mut [f64] = &mut cov.data;
            for w in bounds.windows(2) {
                let rows = w[1] - w[0];
                let (head, tail) = rest.split_at_mut(rows * d);
                stripes.push(head);
                rest = tail;
            }
            let mean_ref = &mean;
            std::thread::scope(|scope| {
                for (w, stripe) in bounds.windows(2).zip(stripes) {
                    let (lo, hi) = (w[0], w[1]);
                    scope.spawn(move || {
                        // per-thread centered tail of each sample (only
                        // xc[lo..] is read by rows lo..hi)
                        let mut xc = vec![0.0f64; d];
                        for row in samples.chunks_exact(d) {
                            crate::simd::center_f32_to_f64(
                                &mut xc[lo..],
                                &row[lo..],
                                &mean_ref[lo..],
                            );
                            for i in lo..hi {
                                let xi = xc[i];
                                if xi == 0.0 {
                                    continue;
                                }
                                let crow = &mut stripe[(i - lo) * d..(i - lo + 1) * d];
                                crate::simd::axpy_f64(&mut crow[i..], xi, &xc[i..]);
                            }
                        }
                    });
                }
            });
        }
        let denom = (n.max(1)) as f64;
        for i in 0..d {
            for j in i..d {
                let v = cov[(i, j)] / denom;
                cov[(i, j)] = v;
                cov[(j, i)] = v;
            }
        }

        let (eigenvalues, basis) = symmetric_eig(&cov);
        Pca {
            basis,
            eigenvalues,
            mean,
        }
    }

    /// Project a sample: c = Uᵀ (x - μ).
    pub fn project(&self, x: &[f32]) -> Vec<f64> {
        let d = self.basis.rows;
        debug_assert_eq!(x.len(), d);
        let xc: Vec<f64> = x
            .iter()
            .zip(&self.mean)
            .map(|(&v, &m)| v as f64 - m)
            .collect();
        // c_j = Σ_i U[i,j] xc[i]
        self.basis.matvec_t(&xc)
    }

    /// Reconstruct from a sparse coefficient set: x ≈ μ + Σ_j U[:, j] c_j.
    pub fn reconstruct_sparse(&self, coeffs: &[(usize, f64)], out: &mut [f32]) {
        let d = self.basis.rows;
        debug_assert_eq!(out.len(), d);
        for (o, &m) in out.iter_mut().zip(&self.mean) {
            *o = m as f32;
        }
        for &(j, c) in coeffs {
            for i in 0..d {
                out[i] += (self.basis[(i, j)] * c) as f32;
            }
        }
    }

    /// Fraction of total variance captured by the top `k` directions.
    pub fn explained_variance(&self, k: usize) -> f64 {
        let total: f64 = self.eigenvalues.iter().map(|v| v.max(0.0)).sum();
        if total <= 0.0 {
            return 1.0;
        }
        self.eigenvalues[..k.min(self.eigenvalues.len())]
            .iter()
            .map(|v| v.max(0.0))
            .sum::<f64>()
            / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    /// Generate samples lying (noisily) on a k-dim subspace of R^d.
    fn low_rank_samples(n: usize, d: usize, k: usize, noise: f64, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        let dirs: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let mut out = vec![0.0f32; n * d];
        for row in out.chunks_exact_mut(d) {
            for dir in &dirs {
                let c = rng.normal() * 3.0;
                for (o, &u) in row.iter_mut().zip(dir) {
                    *o += (c * u) as f32;
                }
            }
            for o in row.iter_mut() {
                *o += (rng.normal() * noise) as f32;
            }
        }
        out
    }

    #[test]
    fn projection_roundtrip_full_basis() {
        let mut rng = Prng::new(2);
        let (n, d) = (50, 12);
        let samples: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let pca = Pca::fit(&samples, n, d, false);
        let x = &samples[..d];
        let c = pca.project(x);
        let all: Vec<(usize, f64)> = c.iter().cloned().enumerate().collect();
        let mut rec = vec![0.0f32; d];
        pca.reconstruct_sparse(&all, &mut rec);
        for (a, b) in x.iter().zip(&rec) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn low_rank_data_captured_by_few_components() {
        let (n, d, k) = (400, 20, 3);
        let samples = low_rank_samples(n, d, k, 1e-3, 4);
        let pca = Pca::fit(&samples, n, d, false);
        assert!(pca.explained_variance(k) > 0.999);
        assert!(pca.explained_variance(1) < 0.999);
    }

    #[test]
    fn eigenvalues_nonincreasing_and_nonnegative() {
        let samples = low_rank_samples(100, 15, 5, 0.1, 8);
        let pca = Pca::fit(&samples, 100, 15, false);
        for w in pca.eigenvalues.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(pca.eigenvalues.iter().all(|&v| v > -1e-9));
    }

    #[test]
    fn parallel_fit_is_bit_identical() {
        // the stripe-parallel covariance must not change a single bit:
        // same eigenvalues, same basis, for any thread count
        // n * d comfortably above PAR_MIN_WORK so the threaded path runs
        let (n, d) = (900, 24);
        let samples = low_rank_samples(n, d, 4, 0.2, 12);
        let seq = Pca::fit_threads(&samples, n, d, false, 1);
        for threads in [2usize, 3, 7, 32] {
            let par = Pca::fit_threads(&samples, n, d, false, threads);
            assert_eq!(seq.basis.data, par.basis.data, "{threads} threads");
            assert_eq!(seq.eigenvalues, par.eigenvalues, "{threads} threads");
            assert_eq!(seq.mean, par.mean, "{threads} threads");
        }
        // centered path too
        let seq = Pca::fit_threads(&samples, n, d, true, 1);
        let par = Pca::fit_threads(&samples, n, d, true, 5);
        assert_eq!(seq.basis.data, par.basis.data);
    }

    #[test]
    fn top_coeff_reconstruction_reduces_error() {
        let (n, d) = (200, 16);
        let samples = low_rank_samples(n, d, 2, 0.05, 6);
        let pca = Pca::fit(&samples, n, d, false);
        let x = &samples[..d];
        let c = pca.project(x);
        let norm = |v: &[f32]| v.iter().map(|a| (*a as f64).powi(2)).sum::<f64>().sqrt();
        let mut best_prev = f64::INFINITY;
        for m in [0usize, 1, 2, d] {
            let top: Vec<(usize, f64)> = (0..m).map(|j| (j, c[j])).collect();
            let mut rec = vec![0.0f32; d];
            pca.reconstruct_sparse(&top, &mut rec);
            let resid: Vec<f32> = x.iter().zip(&rec).map(|(a, b)| a - b).collect();
            let e = norm(&resid);
            assert!(e <= best_prev + 1e-9, "error increased with more coeffs");
            best_prev = e;
        }
        assert!(best_prev < 1e-4);
    }
}
