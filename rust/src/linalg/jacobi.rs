//! Cyclic Jacobi eigensolver for symmetric matrices.
//!
//! Robust and exact enough for the 80x80 residual covariance matrices of
//! Algorithm 1 (converges quadratically; we sweep until the off-diagonal
//! norm is negligible relative to the diagonal).

use crate::linalg::Mat;

/// Eigendecomposition A = V diag(w) Vᵀ of a symmetric matrix.
/// Returns eigenvalues descending with matching eigenvector *columns* in V.
pub fn symmetric_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "symmetric_eig needs a square matrix");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Mat::identity(n);

    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        let diag: f64 = (0..n).map(|i| m[(i, i)] * m[(i, i)]).sum();
        if off <= 1e-26 * diag.max(1e-300) {
            break;
        }

        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    // extract and sort descending
    let mut idx: Vec<usize> = (0..n).collect();
    let w: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| w[b].partial_cmp(&w[a]).unwrap());

    let ws: Vec<f64> = idx.iter().map(|&i| w[i]).collect();
    let mut vs = Mat::zeros(n, n);
    for (newj, &oldj) in idx.iter().enumerate() {
        for i in 0..n {
            vs[(i, newj)] = v[(i, oldj)];
        }
    }
    (ws, vs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn random_symmetric(n: usize, seed: u64) -> Mat {
        let mut rng = Prng::new(seed);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        a
    }

    #[test]
    fn known_2x2() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (w, v) = symmetric_eig(&a);
        assert!((w[0] - 3.0).abs() < 1e-12);
        assert!((w[1] - 1.0).abs() < 1e-12);
        // eigenvector for 3 is (1,1)/sqrt2 up to sign
        assert!((v[(0, 0)].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-10);
    }

    #[test]
    fn reconstructs_matrix() {
        for n in [3, 10, 40, 80] {
            let a = random_symmetric(n, n as u64);
            let (w, v) = symmetric_eig(&a);
            // A v_j = w_j v_j
            for j in 0..n {
                let col: Vec<f64> = (0..n).map(|i| v[(i, j)]).collect();
                let av = a.matvec(&col);
                for i in 0..n {
                    assert!(
                        (av[i] - w[j] * col[i]).abs() < 1e-8,
                        "n={n} j={j} i={i}: {} vs {}",
                        av[i],
                        w[j] * col[i]
                    );
                }
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let a = random_symmetric(30, 5);
        let (_, v) = symmetric_eig(&a);
        let vtv = v.transpose().matmul(&v);
        for i in 0..30 {
            for j in 0..30 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn eigenvalues_descending() {
        let a = random_symmetric(25, 9);
        let (w, _) = symmetric_eig(&a);
        for i in 1..w.len() {
            assert!(w[i - 1] >= w[i] - 1e-12);
        }
    }
}
