//! NRMSE (paper Eq. 3): RMSE normalized by the original data's range.
//! The paper's overall score is the *average of per-species NRMSEs*.
//!
//! The squared-error and min/max sweeps run through [`crate::simd`]'s
//! fixed-lane kernels: the lane order is the canonical reduction order on
//! every ISA, so the reported NRMSE is bit-identical with SIMD on or off.

/// NRMSE of `recon` against `orig`, normalizing by (max - min) of `orig`.
pub fn nrmse(orig: &[f32], recon: &[f32]) -> f64 {
    let (lo, hi) = range(orig);
    nrmse_with_range(orig, recon, lo, hi)
}

/// NRMSE with an explicit normalization range.
pub fn nrmse_with_range(orig: &[f32], recon: &[f32], lo: f32, hi: f32) -> f64 {
    assert_eq!(orig.len(), recon.len());
    if orig.is_empty() {
        return 0.0;
    }
    let mse: f64 = crate::simd::sum_sq_diff(orig, recon) / orig.len() as f64;
    let range = (hi - lo) as f64;
    if range <= 0.0 {
        return if mse == 0.0 { 0.0 } else { f64::INFINITY };
    }
    mse.sqrt() / range
}

fn range(xs: &[f32]) -> (f32, f32) {
    crate::simd::minmax(xs)
}

/// Per-species NRMSE over species-major data `[S, n]` plus their average
/// (the paper's headline PD error).  Returns (per_species, mean).
pub fn nrmse_per_species(orig: &[f32], recon: &[f32], ns: usize) -> (Vec<f64>, f64) {
    assert_eq!(orig.len(), recon.len());
    assert_eq!(orig.len() % ns, 0);
    let n = orig.len() / ns;
    let mut per = Vec::with_capacity(ns);
    for s in 0..ns {
        per.push(nrmse(&orig[s * n..(s + 1) * n], &recon[s * n..(s + 1) * n]));
    }
    let mean = per.iter().sum::<f64>() / ns as f64;
    (per, mean)
}

/// Same but for f64 data (QoI production rates).
pub fn nrmse_per_species_f64(orig: &[f64], recon: &[f64], ns: usize) -> (Vec<f64>, f64) {
    assert_eq!(orig.len(), recon.len());
    let n = orig.len() / ns;
    let mut per = Vec::with_capacity(ns);
    for s in 0..ns {
        let o = &orig[s * n..(s + 1) * n];
        let r = &recon[s * n..(s + 1) * n];
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in o {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let mse = o
            .iter()
            .zip(r)
            .map(|(&a, &b)| (a - b) * (a - b))
            .sum::<f64>()
            / n as f64;
        let range = hi - lo;
        per.push(if range > 0.0 {
            mse.sqrt() / range
        } else if mse == 0.0 {
            0.0
        } else {
            f64::INFINITY
        });
    }
    let mean = per.iter().sum::<f64>() / ns as f64;
    (per, mean)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_for_identical() {
        let a = vec![1.0f32, 2.0, 3.0];
        assert_eq!(nrmse(&a, &a), 0.0);
    }

    #[test]
    fn known_value() {
        let orig = vec![0.0f32, 1.0]; // range 1
        let recon = vec![0.1f32, 1.1];
        assert!((nrmse(&orig, &recon) - 0.1).abs() < 1e-6); // f32 rounding
    }

    #[test]
    fn scale_invariance_via_range() {
        // same relative error at different absolute scales -> same NRMSE;
        // this is why the paper uses NRMSE for species spanning decades
        let o1 = vec![0.0f32, 1e-6];
        let r1 = vec![1e-8f32, 1e-6];
        let o2 = vec![0.0f32, 1.0];
        let r2 = vec![0.01f32, 1.0];
        assert!((nrmse(&o1, &r1) - nrmse(&o2, &r2)).abs() < 1e-9);
    }

    #[test]
    fn per_species_average() {
        let ns = 2;
        let orig = vec![0.0, 1.0, 0.0, 2.0]; // species 0: [0,1], species 1: [0,2]
        let recon = vec![0.1, 1.0, 0.0, 2.0];
        let (per, mean) = nrmse_per_species(&orig, &recon, ns);
        assert!(per[0] > 0.0 && per[1] == 0.0);
        assert!((mean - per[0] / 2.0).abs() < 1e-12);
    }
}
