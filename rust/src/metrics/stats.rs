//! Spatial mean/std per time frame (Figs. 7–8's temporal profiles).

/// (mean, std) of one frame.
pub fn frame_mean_std(frame: &[f32]) -> (f64, f64) {
    let n = frame.len() as f64;
    if frame.is_empty() {
        return (0.0, 0.0);
    }
    let mean = frame.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = frame
        .iter()
        .map(|&v| {
            let d = v as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n;
    (mean, var.sqrt())
}

/// Temporal profiles of a `[T, n]` field: per-frame (mean, std).
pub fn temporal_profiles(field: &[f32], nt: usize) -> Vec<(f64, f64)> {
    assert_eq!(field.len() % nt.max(1), 0);
    let n = field.len() / nt;
    (0..nt)
        .map(|t| frame_mean_std(&field[t * n..(t + 1) * n]))
        .collect()
}

/// Same for f64 fields (QoI rates).
pub fn temporal_profiles_f64(field: &[f64], nt: usize) -> Vec<(f64, f64)> {
    let n = field.len() / nt;
    (0..nt)
        .map(|t| {
            let fr = &field[t * n..(t + 1) * n];
            let mean = fr.iter().sum::<f64>() / n as f64;
            let var = fr.iter().map(|&v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
            (mean, var.sqrt())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_frame() {
        let (m, s) = frame_mean_std(&[2.0; 10]);
        assert_eq!(m, 2.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn known_std() {
        let (m, s) = frame_mean_std(&[0.0, 2.0]);
        assert_eq!(m, 1.0);
        assert_eq!(s, 1.0);
    }

    #[test]
    fn profiles_shape() {
        let field = vec![1.0f32; 3 * 4];
        let p = temporal_profiles(&field, 3);
        assert_eq!(p.len(), 3);
        assert!(p.iter().all(|&(m, s)| m == 1.0 && s == 0.0));
    }
}
