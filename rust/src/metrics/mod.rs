//! Evaluation metrics from the paper's §III: NRMSE (Eq. 3), PSNR, SSIM,
//! and the mean/std temporal profiles of Figs. 7–8.

pub mod nrmse;
pub mod psnr;
pub mod ssim;
pub mod stats;

pub use nrmse::{nrmse, nrmse_per_species, nrmse_with_range};
pub use psnr::{psnr, psnr_with_range};
pub use ssim::{ssim2d, ssim2d_with_range};
pub use stats::{frame_mean_std, temporal_profiles};
