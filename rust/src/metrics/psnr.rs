//! Peak signal-to-noise ratio (dB), peak = range of the original signal.

/// PSNR in dB; +inf for identical inputs (peak = range of `orig`).
pub fn psnr(orig: &[f32], recon: &[f32]) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in orig {
        lo = lo.min(v as f64);
        hi = hi.max(v as f64);
    }
    psnr_with_range(orig, recon, hi - lo)
}

/// PSNR with an explicit dynamic range (e.g. the species-wide range when
/// scoring individual frames of a sequence, as in Figs. 5/6).
pub fn psnr_with_range(orig: &[f32], recon: &[f32], peak: f64) -> f64 {
    assert_eq!(orig.len(), recon.len());
    let mse: f64 = orig
        .iter()
        .zip(recon)
        .map(|(&a, &b)| {
            let d = a as f64 - b as f64;
            d * d
        })
        .sum::<f64>()
        / orig.len() as f64;
    if mse == 0.0 {
        return f64::INFINITY;
    }
    if peak <= 0.0 {
        return 0.0;
    }
    10.0 * (peak * peak / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_is_infinite() {
        let a = vec![0.0f32, 0.5, 1.0];
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn known_value() {
        // range 1, uniform error 0.1 -> psnr = 20 dB
        let orig = vec![0.0f32, 1.0];
        let recon = vec![0.1f32, 0.9];
        assert!((psnr(&orig, &recon) - 20.0).abs() < 1e-4); // f32 rounding
    }

    #[test]
    fn better_recon_higher_psnr() {
        let orig: Vec<f32> = (0..100).map(|i| i as f32 / 100.0).collect();
        let noisy1: Vec<f32> = orig.iter().map(|v| v + 0.01).collect();
        let noisy2: Vec<f32> = orig.iter().map(|v| v + 0.1).collect();
        assert!(psnr(&orig, &noisy1) > psnr(&orig, &noisy2));
    }
}
