//! Structural similarity (Wang et al. 2004) over 2D frames, 8x8 windows,
//! uniform weighting — the paper quotes SSIM per species frame (Figs. 5/6).

const C1_K: f64 = 0.01;
const C2_K: f64 = 0.03;
const WIN: usize = 8;

/// Mean SSIM over non-overlapping 8x8 windows of a `[ny, nx]` frame.
/// Dynamic range is taken from the original frame.
pub fn ssim2d(orig: &[f32], recon: &[f32], ny: usize, nx: usize) -> f64 {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in orig {
        lo = lo.min(v as f64);
        hi = hi.max(v as f64);
    }
    ssim2d_with_range(orig, recon, ny, nx, hi - lo)
}

/// SSIM with an explicit dynamic range (species-wide range for sequence
/// frames, Figs. 5/6 — per-frame ranges collapse pre/post-ignition).
pub fn ssim2d_with_range(
    orig: &[f32],
    recon: &[f32],
    ny: usize,
    nx: usize,
    range: f64,
) -> f64 {
    assert_eq!(orig.len(), ny * nx);
    assert_eq!(recon.len(), ny * nx);
    let l = range.max(1e-300);
    let c1 = (C1_K * l) * (C1_K * l);
    let c2 = (C2_K * l) * (C2_K * l);

    let mut total = 0.0;
    let mut count = 0usize;
    let mut wy = 0;
    while wy < ny {
        let hy = WIN.min(ny - wy);
        let mut wx = 0;
        while wx < nx {
            let hx = WIN.min(nx - wx);
            let n = (hy * hx) as f64;
            let (mut ma, mut mb) = (0.0f64, 0.0f64);
            for y in wy..wy + hy {
                for x in wx..wx + hx {
                    ma += orig[y * nx + x] as f64;
                    mb += recon[y * nx + x] as f64;
                }
            }
            ma /= n;
            mb /= n;
            let (mut va, mut vb, mut cov) = (0.0f64, 0.0f64, 0.0f64);
            for y in wy..wy + hy {
                for x in wx..wx + hx {
                    let da = orig[y * nx + x] as f64 - ma;
                    let db = recon[y * nx + x] as f64 - mb;
                    va += da * da;
                    vb += db * db;
                    cov += da * db;
                }
            }
            va /= n;
            vb /= n;
            cov /= n;
            let s = ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
                / ((ma * ma + mb * mb + c1) * (va + vb + c2));
            total += s;
            count += 1;
            wx += WIN;
        }
        wy += WIN;
    }
    total / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn identical_frames_score_one() {
        let mut rng = Prng::new(1);
        let f: Vec<f32> = (0..32 * 32).map(|_| rng.next_f32()).collect();
        let s = ssim2d(&f, &f, 32, 32);
        assert!((s - 1.0).abs() < 1e-12, "{s}");
    }

    #[test]
    fn noise_lowers_ssim_monotonically() {
        let mut rng = Prng::new(2);
        let ny = 40;
        let nx = 40;
        // smooth frame
        let f: Vec<f32> = (0..ny * nx)
            .map(|i| {
                let (y, x) = (i / nx, i % nx);
                (y as f32 / 8.0).sin() + (x as f32 / 6.0).cos()
            })
            .collect();
        let noisy = |amp: f32, rng: &mut Prng| -> Vec<f32> {
            f.iter().map(|v| v + amp * rng.normal() as f32).collect()
        };
        let s1 = ssim2d(&f, &noisy(0.01, &mut rng), ny, nx);
        let s2 = ssim2d(&f, &noisy(0.2, &mut rng), ny, nx);
        assert!(s1 > s2, "{s1} vs {s2}");
        assert!(s1 > 0.9 && s2 < 0.9);
    }

    #[test]
    fn bounded_by_one() {
        let mut rng = Prng::new(3);
        let a: Vec<f32> = (0..24 * 24).map(|_| rng.next_f32()).collect();
        let b: Vec<f32> = (0..24 * 24).map(|_| rng.next_f32()).collect();
        let s = ssim2d(&a, &b, 24, 24);
        assert!(s <= 1.0 + 1e-12 && s >= -1.0);
    }
}
