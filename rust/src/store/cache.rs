//! Sharded, byte-metered LRU cache of decoded (dataset, shard, species)
//! planes.
//!
//! The hot path of a query server is *re*-decoding: post-hoc analysis
//! issues many small overlapping spatiotemporal/species queries against
//! the same reduced dataset, and every one of them would otherwise pay
//! the AE+TCN reconstruction and entropy decode again.  This cache keeps
//! the decoded **normalized per-species planes** (`[nt_sh, Y, X]` f32)
//! keyed by `(dataset id, shard index, species index)` — the exact unit
//! [`ShardEngine::decode_shard_planes`](crate::coordinator::engine::ShardEngine::decode_shard_planes)
//! produces deterministically, so a response assembled from cached planes
//! is bit-identical to an uncached decode.
//!
//! **Sharing contract**: planes are stored as `Arc<[f32]>` and are
//! immutable once inserted — the decode fills the allocation *before*
//! the `Arc` is shared (`Arc::get_mut` on the still-unique handle), and
//! no API ever hands out mutable access afterwards.  A warm hit is
//! therefore one refcount bump; readers denormalize straight out of the
//! shared allocation and never copy the plane.
//!
//! Concurrency: the key space is split over `lock_shards` independent
//! `Mutex`es (key-hash selects the lock), so concurrent queries touching
//! different planes never serialize on a global mutex; the only shared
//! mutable state on the hot path is one atomic recency counter.  The byte
//! budget is divided evenly across lock shards and enforced per shard —
//! each insert evicts that shard's least-recently-used planes until its
//! slice of the budget holds.  Entries larger than one shard's slice are
//! never admitted (counted in `rejected`): a plane that would evict an
//! entire lock shard's working set is better decoded on demand.

use std::collections::{BTreeMap, HashMap};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// `(dataset id, shard index, species index)`.
pub type CacheKey = (u32, u32, u32);

/// Bookkeeping bytes charged per resident entry on top of the plane
/// itself (map slot + LRU order node, roughly).
const ENTRY_OVERHEAD: usize = 96;

struct Slot {
    /// Shared plane storage: a hit hands out an `Arc` clone (one
    /// refcount bump, zero bytes of plane data copied) of the same
    /// allocation the decode filled.
    plane: Arc<[f32]>,
    stamp: u64,
    bytes: usize,
}

#[derive(Default)]
struct Shard {
    map: HashMap<CacheKey, Slot>,
    /// Recency order: stamp -> key.  Stamps come from one global monotone
    /// counter, so they are unique and the first entry is the LRU.
    order: BTreeMap<u64, CacheKey>,
    bytes: usize,
}

/// Counter snapshot of a [`SectionCache`]; see the field docs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Plane lookups served from the cache.
    pub hits: u64,
    /// Plane lookups that required a decode.
    pub misses: u64,
    /// Planes admitted (inserted or replaced).
    pub admitted: u64,
    /// Planes refused admission (larger than one lock shard's budget).
    pub rejected: u64,
    /// Planes evicted to make room.
    pub evicted: u64,
    /// Planes currently resident.
    pub resident_sections: u64,
    /// Bytes currently resident (planes + per-entry overhead).
    pub resident_bytes: u64,
    /// Configured byte budget.
    pub capacity_bytes: u64,
    /// Independent lock shards.
    pub lock_shards: u64,
}

impl CacheStats {
    /// Hit fraction of all lookups (0 when none were made).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl fmt::Display for CacheStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} hits / {} misses ({:.1}%) | resident {} planes {} B of {} B | \
             admitted {} rejected {} evicted {}",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.resident_sections,
            self.resident_bytes,
            self.capacity_bytes,
            self.admitted,
            self.rejected,
            self.evicted
        )
    }
}

/// The sharded LRU itself; see the module docs.
pub struct SectionCache {
    shards: Vec<Mutex<Shard>>,
    /// Byte budget of one lock shard (total capacity / lock shards).
    per_shard_cap: usize,
    capacity: usize,
    stamp: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    evicted: AtomicU64,
}

impl SectionCache {
    /// A cache with `capacity` bytes split over `lock_shards` mutexes.
    pub fn new(capacity: usize, lock_shards: usize) -> SectionCache {
        let n = lock_shards.max(1);
        SectionCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            per_shard_cap: (capacity / n).max(1),
            capacity,
            stamp: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    fn next_stamp(&self) -> u64 {
        self.stamp.fetch_add(1, Ordering::Relaxed)
    }

    /// A panic while a lock was held must not wedge the whole server;
    /// the map/order invariants are maintained by value updates, so the
    /// inner state stays usable.
    fn lock(&self, key: CacheKey) -> MutexGuard<'_, Shard> {
        let mut h = (key.0 as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= (key.1 as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
        h ^= (key.2 as u64).wrapping_mul(0x1656_67B1_9E37_79F9);
        h ^= h >> 29;
        let idx = (h as usize) % self.shards.len();
        match self.shards[idx].lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Look a plane up, refreshing its recency on a hit.  A hit is a
    /// refcount bump on the resident allocation — never a plane copy
    /// (`warm_hits_share_one_allocation` asserts pointer identity).
    pub fn get(&self, key: CacheKey) -> Option<Arc<[f32]>> {
        let found = {
            let mut guard = self.lock(key);
            let sh = &mut *guard;
            match sh.map.get_mut(&key) {
                Some(slot) => {
                    let stamp = self.stamp.fetch_add(1, Ordering::Relaxed);
                    let old = slot.stamp;
                    slot.stamp = stamp;
                    let plane = slot.plane.clone();
                    sh.order.remove(&old);
                    sh.order.insert(stamp, key);
                    Some(plane)
                }
                None => None,
            }
        };
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Residency probe with **no side effects**: no hit/miss counters,
    /// no recency refresh.  The serve tier probes warmth to decide
    /// inline-vs-worker execution and then runs the real query — using
    /// `get` here would double-count every probed lookup.
    pub fn peek(&self, key: CacheKey) -> bool {
        self.lock(key).map.contains_key(&key)
    }

    /// Admit a freshly decoded plane, evicting this lock shard's LRU
    /// entries until its slice of the byte budget holds.  Returns whether
    /// the plane was admitted.  Two threads racing the same miss both
    /// insert; the later call replaces the earlier plane (same bits — the
    /// decode is deterministic), which only costs the duplicate decode.
    pub fn insert(&self, key: CacheKey, plane: Arc<[f32]>) -> bool {
        let bytes = plane.len() * 4 + ENTRY_OVERHEAD;
        if bytes > self.per_shard_cap {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        let mut evictions = 0u64;
        {
            let mut guard = self.lock(key);
            let sh = &mut *guard;
            let stamp = self.next_stamp();
            if let Some(old) = sh.map.insert(key, Slot { plane, stamp, bytes }) {
                sh.order.remove(&old.stamp);
                sh.bytes -= old.bytes;
            }
            sh.order.insert(stamp, key);
            sh.bytes += bytes;
            while sh.bytes > self.per_shard_cap {
                // the loop terminates: the entry just inserted alone fits
                let Some((_, victim)) = sh.order.pop_first() else {
                    break;
                };
                if let Some(slot) = sh.map.remove(&victim) {
                    sh.bytes -= slot.bytes;
                    evictions += 1;
                }
            }
        }
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.evicted.fetch_add(evictions, Ordering::Relaxed);
        true
    }

    /// Drop every plane of one dataset (unmount support).
    pub fn purge_dataset(&self, dataset: u32) {
        for m in &self.shards {
            let mut guard = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            let sh = &mut *guard;
            let victims: Vec<CacheKey> = sh
                .map
                .keys()
                .filter(|k| k.0 == dataset)
                .copied()
                .collect();
            for k in victims {
                if let Some(slot) = sh.map.remove(&k) {
                    sh.order.remove(&slot.stamp);
                    sh.bytes -= slot.bytes;
                }
            }
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut resident_sections = 0u64;
        let mut resident_bytes = 0u64;
        for m in &self.shards {
            let guard = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            resident_sections += guard.map.len() as u64;
            resident_bytes += guard.bytes as u64;
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            resident_sections,
            resident_bytes,
            capacity_bytes: self.capacity as u64,
            lock_shards: self.shards.len() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(v: f32, n: usize) -> Arc<[f32]> {
        Arc::from(vec![v; n])
    }

    #[test]
    fn hit_miss_and_recency() {
        let c = SectionCache::new(1 << 20, 4);
        assert!(c.get((0, 0, 0)).is_none());
        assert!(c.insert((0, 0, 0), plane(1.0, 10)));
        let got = c.get((0, 0, 0)).expect("hit");
        assert_eq!(got[0], 1.0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.admitted), (1, 1, 1));
        assert_eq!(s.resident_sections, 1);
        assert!(s.resident_bytes >= 40);
    }

    /// The zero-copy contract: every warm hit returns the *same
    /// allocation* that was inserted — pointer identity, not an equal
    /// copy — so a hit moves zero plane bytes.
    #[test]
    fn warm_hits_share_one_allocation() {
        let c = SectionCache::new(1 << 20, 2);
        let p: Arc<[f32]> = Arc::from(vec![3.5f32; 500]);
        assert!(c.insert((7, 1, 2), Arc::clone(&p)));
        let a = c.get((7, 1, 2)).expect("hit");
        let b = c.get((7, 1, 2)).expect("hit");
        assert!(Arc::ptr_eq(&a, &p), "hit must alias the inserted plane");
        assert!(Arc::ptr_eq(&a, &b), "every hit aliases the same plane");
        // original + resident slot + two hits
        assert_eq!(Arc::strong_count(&p), 4);
        assert_eq!(&a[..], &p[..]);
    }

    #[test]
    fn evicts_lru_within_byte_budget() {
        // one lock shard so the budget and the order are deterministic;
        // room for two 100-f32 planes (400 B + overhead each), not three
        let c = SectionCache::new(2 * (400 + ENTRY_OVERHEAD) + 50, 1);
        assert!(c.insert((0, 0, 0), plane(0.0, 100)));
        assert!(c.insert((0, 0, 1), plane(1.0, 100)));
        // refresh (0,0,0) so (0,0,1) is the LRU
        assert!(c.get((0, 0, 0)).is_some());
        assert!(c.insert((0, 0, 2), plane(2.0, 100)));
        assert!(c.get((0, 0, 1)).is_none(), "LRU entry must be evicted");
        assert!(c.get((0, 0, 0)).is_some());
        assert!(c.get((0, 0, 2)).is_some());
        let s = c.stats();
        assert_eq!(s.evicted, 1);
        assert_eq!(s.resident_sections, 2);
        assert!(s.resident_bytes <= s.capacity_bytes);
    }

    #[test]
    fn oversized_planes_are_rejected_and_replace_updates_bytes() {
        let c = SectionCache::new(1000, 1);
        assert!(!c.insert((0, 0, 0), plane(0.0, 100_000)));
        assert_eq!(c.stats().rejected, 1);
        assert_eq!(c.stats().resident_sections, 0);
        // replacing a key keeps byte accounting exact
        assert!(c.insert((0, 0, 1), plane(1.0, 50)));
        let before = c.stats().resident_bytes;
        assert!(c.insert((0, 0, 1), plane(2.0, 50)));
        assert_eq!(c.stats().resident_bytes, before);
        assert_eq!(c.stats().resident_sections, 1);
        assert_eq!(c.get((0, 0, 1)).expect("hit")[0], 2.0);
    }

    #[test]
    fn purge_dataset_frees_only_that_dataset() {
        let c = SectionCache::new(1 << 20, 8);
        for s in 0..10u32 {
            assert!(c.insert((1, 0, s), plane(1.0, 10)));
            assert!(c.insert((2, 0, s), plane(2.0, 10)));
        }
        c.purge_dataset(1);
        let s = c.stats();
        assert_eq!(s.resident_sections, 10);
        assert!(c.get((1, 0, 3)).is_none());
        assert!(c.get((2, 0, 3)).is_some());
    }

    #[test]
    fn concurrent_mixed_ops_keep_counters_consistent() {
        let c = Arc::new(SectionCache::new(64 << 10, 4));
        std::thread::scope(|scope| {
            for w in 0..4u32 {
                let c = Arc::clone(&c);
                scope.spawn(move || {
                    for i in 0..200u32 {
                        let key = (w % 2, i % 16, (i * 7) % 8);
                        if c.get(key).is_none() {
                            c.insert(key, plane(i as f32, 64));
                        }
                    }
                });
            }
        });
        let s = c.stats();
        assert_eq!(s.hits + s.misses, 800);
        assert!(s.resident_bytes <= s.capacity_bytes);
        assert!(s.resident_sections > 0);
    }
}
