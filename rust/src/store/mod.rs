//! Multi-archive query-serving store.
//!
//! [`ArchiveStore`] mounts many `GBA1`/`GBA2` archives under named
//! dataset keys and executes typed [`Query`]s against them through a
//! sharded LRU cache of decoded (shard, species) planes
//! ([`SectionCache`]).  It is the process-wide read side the network
//! server ([`crate::serve`]) fronts: one executor service, one cache, any
//! number of mounted datasets, any number of querying threads.
//!
//! * **Cache unit** — the normalized per-species plane of one shard
//!   (`[nt_sh, Y, X]` f32, held as `Arc<[f32]>`), exactly what
//!   [`ShardEngine::decode_shard_planes_into`](crate::coordinator::engine::ShardEngine::decode_shard_planes_into)
//!   produces.  Misses decode **directly into** the plane allocation
//!   that the cache will own (no post-decode copy), and warm hits hand
//!   back an `Arc` clone — a refcount bump, zero plane bytes moved.
//!   Decode is deterministic, so responses assembled from cached planes
//!   are **bit-identical** to a fresh `decompress_range` —
//!   property-tested in `tests/query_store.rs`.
//! * **Locking** — per-lock-shard mutexes in the cache plus an `RwLock`
//!   around the mount table (write-locked only by mount/unmount); the
//!   query hot path takes no global mutex.
//! * **Metering** — hit/miss/eviction counters ([`CacheStats`]),
//!   decoded-section/bytes totals, and per-dataset IO counters
//!   ([`crate::archive::IoStats`], header/TOC and payload classified)
//!   surfaced through [`StoreStats`] and the server's `/stats` endpoint.
//!
//! A warm cache makes repeated analysis queries decode-free *and*
//! IO-free: the TOC is parsed once at mount, so a fully cached query
//! touches neither the archive source nor the executor.

pub mod cache;

pub use cache::{CacheStats, SectionCache};

use std::collections::HashMap;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::api::reader::{open_metered, payload_base, v2_bytes};
use crate::api::{Backend, Query};
use crate::archive::{
    Gba2Archive, Gba2Header, IoStats, MemSource, MeteredSource, SectionSource, ShardToc,
};
use crate::compressor::SectionSalvage;
use crate::coordinator::engine::{denorm_row_into, RangeDecode, ShardEngine};
use crate::error::{Error, Result};
use crate::obs::{HistSnapshot, Histogram, Phase, SpanBuilder};
use crate::runtime::{ExecHandle, ExecService};

/// Store-side latency histograms — the `/metrics` feeds the serve layer
/// merges across replicas.  Record path is the lock-free integer path
/// of [`Histogram`]; see [`crate::obs`].
#[derive(Debug, Default)]
pub struct StoreObs {
    /// One engine decode pass (batch fill or per-species retry), ns.
    pub decode_ns: Histogram,
    /// Total cache-probe time of one query (all shard×species lookups
    /// summed, one sample per query), ns.
    pub probe_ns: Histogram,
}

impl StoreObs {
    /// Plain-data copy for merging and export.
    pub fn snapshot(&self) -> StoreObsSnapshot {
        StoreObsSnapshot {
            decode_ns: self.decode_ns.snapshot(),
            probe_ns: self.probe_ns.snapshot(),
        }
    }
}

/// Snapshot of [`StoreObs`]; [`merge`](Self::merge) folds replicas.
#[derive(Clone, Debug, Default)]
pub struct StoreObsSnapshot {
    pub decode_ns: HistSnapshot,
    pub probe_ns: HistSnapshot,
}

impl StoreObsSnapshot {
    pub fn merge(&mut self, other: &StoreObsSnapshot) {
        self.decode_ns.merge(&other.decode_ns);
        self.probe_ns.merge(&other.probe_ns);
    }
}

/// Knobs of an [`ArchiveStore`].
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Execution backend for shard decodes (the store starts one service
    /// shared by all datasets and queries).
    pub backend: Backend,
    /// Worker threads per query decode (0 = all cores).
    pub threads: usize,
    /// Byte budget of the decoded-plane cache.
    pub cache_bytes: usize,
    /// Independent lock shards of the cache.
    pub cache_shards: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            backend: Backend::Reference,
            threads: 0,
            cache_bytes: 256 << 20,
            cache_shards: 16,
        }
    }
}

/// One mounted archive: its parsed index plus the metered byte source.
struct Mount {
    id: u32,
    src: MeteredSource,
    header: Gba2Header,
    toc: Vec<ShardToc>,
    /// Per-section health: (shard, species) pairs whose decode failed,
    /// with the salvage stats of the last best-effort reconstruction.
    /// Quarantined sections are served degraded instead of failing the
    /// query, and their planes are **never** admitted to the cache.
    quarantine: RwLock<HashMap<(u32, u32), SectionSalvage>>,
}

impl Mount {
    fn is_quarantined(&self, shard: usize, species: usize) -> bool {
        self.quarantine
            .read()
            .map(|g| g.contains_key(&(shard as u32, species as u32)))
            .unwrap_or(false)
    }

    fn set_quarantined(&self, shard: usize, species: usize, stats: SectionSalvage) {
        if let Ok(mut g) = self.quarantine.write() {
            g.insert((shard as u32, species as u32), stats);
        }
    }
}

/// Loosened certified NRMSE bound for one salvaged section.
///
/// Healthy blocks keep the archive's per-block residual bound
/// `τ = target·√D`; a block whose correction was lost is off by that
/// correction on top, estimated by the largest correction ℓ2 observed
/// among the blocks that *did* survive.  Mean-square over the section:
///
/// ```text
/// bound = target · √( f + (1 − f) · ((τ + Ĉ)/τ)² )
/// ```
///
/// with `f` the salvaged block fraction and `Ĉ` the observed max
/// correction norm.  `None` when nothing survived (`f = 0`) — with no
/// surviving blocks there is no data to estimate the lost corrections
/// from, so no bound can be stated.
fn loosened_bound(target: f64, block_d: usize, s: SectionSalvage) -> Option<f64> {
    if s.salvaged_fraction <= 0.0 {
        return None;
    }
    if s.salvaged_fraction >= 1.0 {
        return Some(target);
    }
    let tau = target * (block_d as f64).sqrt();
    if tau <= 0.0 {
        return None;
    }
    let ratio = (tau + s.max_correction) / tau;
    Some(target * (s.salvaged_fraction + (1.0 - s.salvaged_fraction) * ratio * ratio).sqrt())
}

/// Catalog info for one mounted dataset (the `/datasets` endpoint body).
#[derive(Clone, Debug)]
pub struct DatasetInfo {
    pub name: String,
    /// `[T, S, Y, X]`.
    pub dims: (usize, usize, usize, usize),
    pub n_shards: usize,
    pub kt_window: usize,
    /// Loosest certified NRMSE target (per-species budgets are tighter).
    pub nrmse_target: f64,
    pub pressure: f64,
    pub archive_bytes: u64,
    /// Classified archive reads since mount.
    pub io: IoStats,
}

/// Counter snapshot of a store — cache, decode, and per-dataset IO.
#[derive(Clone, Debug)]
pub struct StoreStats {
    /// Queries served (all datasets).
    pub queries: u64,
    /// (shard, species) planes decoded — cache misses that did work.
    pub decoded_sections: u64,
    /// Decoded f32 bytes those planes amount to.
    pub decoded_bytes: u64,
    pub cache: CacheStats,
    pub datasets: Vec<DatasetInfo>,
}

impl fmt::Display for StoreStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} queries | decoded {} sections ({} B) | cache {} | {} datasets",
            self.queries,
            self.decoded_sections,
            self.decoded_bytes,
            self.cache,
            self.datasets.len()
        )
    }
}

/// The multi-archive store; see the module docs.
///
/// ```
/// use std::io::Cursor;
/// use std::sync::Arc;
/// use gbatc::api::{CompressorBuilder, ErrorPolicy, FieldSpec, Query, SpeciesSel};
/// use gbatc::store::{ArchiveStore, StoreConfig};
///
/// # let (nt, ns, ny, nx) = (4, 58, 5, 4);
/// # let field = FieldSpec { nt, ns, ny, nx, pressure: 40.0e5, ranges: vec![(0.0, 1.0); ns] };
/// # let mut session = CompressorBuilder::new()
/// #     .error_policy(ErrorPolicy::Uniform(1e-2))
/// #     .session(field, Cursor::new(Vec::new()))?;
/// # for t in 0..nt {
/// #     let frame: Vec<f32> = (0..ns * ny * nx)
/// #         .map(|i| 0.5 + 0.3 * ((i + t * 31) as f32 * 0.11).sin())
/// #         .collect();
/// #     session.push_timestep(&frame)?;
/// # }
/// # let (_report, sink) = session.finish_into()?;
/// let store = Arc::new(ArchiveStore::new(StoreConfig::default())?);
/// store.mount_bytes("hcci", sink.into_inner())?;
///
/// let q = Query { time: 0..2, species: SpeciesSel::Names(vec!["OH".into()]) };
/// let cold = store.query("hcci", &q)?;
/// let warm = store.query("hcci", &q)?;          // served from the cache
/// assert_eq!(cold.mass, warm.mass);             // bit-identical
/// let stats = store.stats();
/// assert_eq!(stats.cache.hits, 1);              // second query hit
/// assert_eq!(stats.decoded_sections, 1);        // ...and decoded nothing
/// # Ok::<(), gbatc::Error>(())
/// ```
pub struct ArchiveStore {
    /// Keeps a store-started service alive (`with_handle` borrows an
    /// external one instead).
    _service: Option<ExecService>,
    handle: ExecHandle,
    threads: usize,
    cache: SectionCache,
    mounts: RwLock<HashMap<String, Arc<Mount>>>,
    next_id: AtomicU32,
    queries: AtomicU64,
    decoded_sections: AtomicU64,
    decoded_bytes: AtomicU64,
    obs: StoreObs,
}

impl ArchiveStore {
    /// Start the configured backend and open an empty store.
    pub fn new(cfg: StoreConfig) -> Result<ArchiveStore> {
        let (service, _, _) = cfg.backend.start(4)?;
        let handle = service.handle();
        Ok(Self::build(Some(service), handle, &cfg))
    }

    /// A store on an already-running executor handle (no second service
    /// is spawned; `cfg.backend` is ignored).
    pub fn with_handle(handle: &ExecHandle, cfg: StoreConfig) -> ArchiveStore {
        Self::build(None, handle.clone(), &cfg)
    }

    fn build(service: Option<ExecService>, handle: ExecHandle, cfg: &StoreConfig) -> ArchiveStore {
        ArchiveStore {
            _service: service,
            handle,
            threads: cfg.threads,
            cache: SectionCache::new(cfg.cache_bytes, cfg.cache_shards),
            mounts: RwLock::new(HashMap::new()),
            next_id: AtomicU32::new(0),
            queries: AtomicU64::new(0),
            decoded_sections: AtomicU64::new(0),
            decoded_bytes: AtomicU64::new(0),
            obs: StoreObs::default(),
        }
    }

    /// The store's latency histograms (decode, cache probe).
    pub fn obs(&self) -> &StoreObs {
        &self.obs
    }

    /// Mount an archive file under `name`.  `GBA2` files stay on disk
    /// and are read section by section; legacy `GBA1` files are converted
    /// to their one-shard `GBA2` view in memory.
    pub fn mount_file<P: AsRef<Path>>(&self, name: &str, path: P) -> Result<()> {
        self.mount_src(name, open_metered(path.as_ref())?)
    }

    /// Mount serialized archive bytes of either container version.
    pub fn mount_bytes(&self, name: &str, bytes: Vec<u8>) -> Result<()> {
        self.mount_src(
            name,
            MeteredSource::new(Box::new(MemSource(v2_bytes(bytes)?))),
        )
    }

    fn mount_src(&self, name: &str, src: MeteredSource) -> Result<()> {
        if name.is_empty() || name.contains(|c: char| c == '&' || c == '=' || c.is_whitespace()) {
            return Err(Error::config(format!(
                "dataset name `{name}` must be non-empty without `&`, `=`, or whitespace \
                 (it travels in query strings)"
            )));
        }
        let (header, toc) = Gba2Archive::read_toc(&src)?;
        // fail at mount, not first query, if the archive needs a
        // different model than the store's executor serves
        ShardEngine::new(&self.handle, 0, 0).check_spec(&header)?;
        src.set_header_limit(payload_base(&toc, &src));
        let mount = Arc::new(Mount {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            src,
            header,
            toc,
            quarantine: RwLock::new(HashMap::new()),
        });
        let mut guard = self
            .mounts
            .write()
            .map_err(|_| Error::runtime("store mount table lock poisoned"))?;
        if guard.contains_key(name) {
            return Err(Error::config(format!(
                "dataset `{name}` is already mounted (unmount it first)"
            )));
        }
        guard.insert(name.to_string(), mount);
        Ok(())
    }

    /// Unmount a dataset and purge its cached planes.
    pub fn unmount(&self, name: &str) -> Result<()> {
        let mount = {
            let mut guard = self
                .mounts
                .write()
                .map_err(|_| Error::runtime("store mount table lock poisoned"))?;
            guard
                .remove(name)
                .ok_or_else(|| Error::config(format!("no dataset `{name}` mounted")))?
        };
        self.cache.purge_dataset(mount.id);
        Ok(())
    }

    /// Whether `name` is currently mounted.
    pub fn contains(&self, name: &str) -> bool {
        self.mounts
            .read()
            .map(|g| g.contains_key(name))
            .unwrap_or(false)
    }

    fn mount(&self, name: &str) -> Result<Arc<Mount>> {
        let guard = self
            .mounts
            .read()
            .map_err(|_| Error::runtime("store mount table lock poisoned"))?;
        guard.get(name).cloned().ok_or_else(|| {
            let mut names: Vec<&str> = guard.keys().map(|s| s.as_str()).collect();
            names.sort_unstable();
            Error::config(format!(
                "no dataset `{name}` mounted (available: {})",
                if names.is_empty() {
                    "none".to_string()
                } else {
                    names.join(", ")
                }
            ))
        })
    }

    /// Catalog of mounted datasets, sorted by name.
    pub fn datasets(&self) -> Vec<DatasetInfo> {
        let guard = match self.mounts.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let mut out: Vec<DatasetInfo> = guard
            .iter()
            .map(|(name, m)| DatasetInfo {
                name: name.clone(),
                dims: m.header.dims,
                n_shards: m.toc.len(),
                kt_window: m.header.kt_window,
                nrmse_target: m.header.nrmse_target,
                pressure: m.header.pressure,
                archive_bytes: m.src.source_len(),
                io: m.src.stats(),
            })
            .collect();
        out.sort_by(|a, b| a.name.cmp(&b.name));
        out
    }

    /// Catalog entry of one mounted dataset.
    pub fn dataset_info(&self, name: &str) -> Result<DatasetInfo> {
        let m = self.mount(name)?;
        Ok(DatasetInfo {
            name: name.to_string(),
            dims: m.header.dims,
            n_shards: m.toc.len(),
            kt_window: m.header.kt_window,
            nrmse_target: m.header.nrmse_target,
            pressure: m.header.pressure,
            archive_bytes: m.src.source_len(),
            io: m.src.stats(),
        })
    }

    /// Execute a typed query against a mounted dataset through the plane
    /// cache.  Missing planes of each touched shard are decoded in one
    /// engine pass and admitted; the response is assembled with the exact
    /// per-element ops
    /// [`decompress_range`](crate::coordinator::engine::ShardEngine::decompress_range)
    /// runs, so cached and uncached reads return bit-identical bytes.
    ///
    /// `peak_workspace_bytes` of the result covers the response buffer
    /// (the shard-decode internals are metered by the engine pass and
    /// bounded by one shard, as always).
    ///
    /// **Degraded mode** — a section whose decode fails (rotted bytes)
    /// is quarantined in its [`Mount`] instead of failing the query:
    /// its plane is reconstructed best-effort
    /// ([`ShardEngine::decode_shard_plane_salvage`]), served with
    /// `degraded` listing the affected (shard, species) pairs and
    /// `degraded_bound` carrying the loosened certified bound, and
    /// **never** admitted to the cache (so `is_warm` stays false and the
    /// reactor never serves it inline).  Healthy queries take exactly
    /// the pre-quarantine path and return bit-identical bytes.
    pub fn query(&self, dataset: &str, q: &Query) -> Result<RangeDecode> {
        self.query_traced(dataset, q, None)
    }

    /// [`query`](Self::query) with phase attribution: cache-probe,
    /// decode, and salvage time land in `span` (when given) and in the
    /// store's histograms ([`StoreObs`]) always.  `query` is this with
    /// `span = None`.
    pub fn query_traced(
        &self,
        dataset: &str,
        q: &Query,
        mut span: Option<&mut SpanBuilder>,
    ) -> Result<RangeDecode> {
        let m = self.mount(dataset)?;
        let (nt, ns, ny, nx) = m.header.dims;
        let sel = q.species.resolve(ns)?;
        let (t0, t1) = (q.time.start, q.time.end);
        if t0 >= t1 || t1 > nt {
            return Err(Error::shape(format!(
                "time range [{t0}, {t1}) out of bounds for nt {nt}"
            )));
        }
        self.queries.fetch_add(1, Ordering::Relaxed);
        let npix = ny * nx;
        let nsel = sel.len();
        let block_d = m.header.block.0 * m.header.block.1 * m.header.block.2;
        let mut out = vec![0.0f32; (t1 - t0) * nsel * npix];
        let engine = ShardEngine::new(&self.handle, 0, 0);
        let mut degraded: Vec<(usize, usize)> = Vec::new();
        // loosest statable bound among degraded sections; unknown wins
        let mut worst_bound: Option<f64> = None;
        let mut bound_unknown = false;
        let mut note_degraded = |si: usize, s: usize, stats: SectionSalvage| {
            degraded.push((si, s));
            match loosened_bound(m.header.nrmse_target, block_d, stats) {
                Some(b) => worst_bound = Some(worst_bound.map_or(b, |w: f64| w.max(b))),
                None => bound_unknown = true,
            }
        };
        // one denormalized-shard scratch reused across every missing
        // shard of this query (arena reuse; decode_shard_planes_into
        // sizes it per shard)
        let mut norm_scratch: Vec<f32> = Vec::new();
        // probe time accumulates across shards; one histogram sample
        // per query (a query's probe cost, not a per-lookup figure)
        let mut probe_total_ns = 0u64;
        for (si, entry) in m.toc.iter().enumerate() {
            if entry.t0 >= t1 || entry.t0 + entry.nt <= t0 {
                continue;
            }
            // cache lookups per (shard, species); collect what's missing
            let t_probe = Instant::now();
            let mut planes: Vec<Option<Arc<[f32]>>> = sel
                .iter()
                .map(|&s| self.cache.get((m.id, si as u32, s as u32)))
                .collect();
            let probe_ns = t_probe.elapsed().as_nanos() as u64;
            probe_total_ns += probe_ns;
            if let Some(sp) = span.as_deref_mut() {
                let end = sp.mark();
                sp.add_phase(Phase::CacheProbe, end.saturating_sub(probe_ns), probe_ns);
            }
            let plane_len = entry.nt * npix;
            // already-quarantined sections go straight to salvage — they
            // never touch the batch decode, and never enter the cache
            let mut batch_pos: Vec<usize> = Vec::new();
            for k in (0..nsel).filter(|&k| planes[k].is_none()) {
                if m.is_quarantined(si, sel[k]) {
                    let t_salv = Instant::now();
                    let (plane, stats) =
                        engine.decode_shard_plane_salvage(&m.header, entry, &m.src, sel[k])?;
                    let salv_ns = t_salv.elapsed().as_nanos() as u64;
                    if let Some(sp) = span.as_deref_mut() {
                        let end = sp.mark();
                        sp.add_phase(Phase::Salvage, end.saturating_sub(salv_ns), salv_ns);
                    }
                    m.set_quarantined(si, sel[k], stats);
                    note_degraded(si, sel[k], stats);
                    planes[k] = Some(Arc::from(plane));
                } else {
                    batch_pos.push(k);
                }
            }
            if !batch_pos.is_empty() {
                let batch_sel: Vec<usize> = batch_pos.iter().map(|&k| sel[k]).collect();
                // allocate the exact planes the cache will own and decode
                // straight into them — the `Arc`s are uniquely held here,
                // so `get_mut` hands out the fill buffers without a copy
                let mut fresh: Vec<Arc<[f32]>> = batch_pos
                    .iter()
                    .map(|_| Arc::<[f32]>::from(vec![0.0f32; plane_len]))
                    .collect();
                let t_dec = Instant::now();
                let batch = {
                    // the Arcs were allocated two lines up and never
                    // cloned, so get_mut always succeeds; a typed error
                    // keeps the request path panic-free regardless
                    let mut outs: Vec<&mut [f32]> = Vec::with_capacity(fresh.len());
                    let mut aliased = false;
                    for a in fresh.iter_mut() {
                        match Arc::get_mut(a) {
                            Some(buf) => outs.push(buf),
                            None => aliased = true,
                        }
                    }
                    if aliased {
                        Err(Error::runtime(
                            "decode plane buffer unexpectedly shared before fill",
                        ))
                    } else {
                        engine.decode_shard_planes_into(
                            &m.header,
                            entry,
                            &m.src,
                            &batch_sel,
                            self.threads,
                            &mut norm_scratch,
                            &mut outs,
                        )
                    }
                };
                let dec_ns = t_dec.elapsed().as_nanos() as u64;
                self.obs.decode_ns.record(dec_ns);
                if let Some(sp) = span.as_deref_mut() {
                    let end = sp.mark();
                    sp.add_phase(Phase::Decode, end.saturating_sub(dec_ns), dec_ns);
                }
                match batch {
                    Ok(()) => {
                        self.decoded_sections
                            .fetch_add(fresh.len() as u64, Ordering::Relaxed);
                        for (&k, plane) in batch_pos.iter().zip(fresh) {
                            self.decoded_bytes
                                .fetch_add(plane.len() as u64 * 4, Ordering::Relaxed);
                            self.cache
                                .insert((m.id, si as u32, sel[k] as u32), Arc::clone(&plane));
                            planes[k] = Some(plane);
                        }
                    }
                    // the batch shares one decode pass, so a single rotten
                    // section fails all of it — retry per species: healthy
                    // sections admit normally, the damaged ones quarantine
                    // and serve salvage (genuine I/O failures still error
                    // out of the salvage decode below)
                    Err(_) => {
                        for &k in &batch_pos {
                            let s = sel[k];
                            let mut one = Arc::<[f32]>::from(vec![0.0f32; plane_len]);
                            let t_one = Instant::now();
                            let single = match Arc::get_mut(&mut one) {
                                Some(buf) => engine.decode_shard_planes_into(
                                    &m.header,
                                    entry,
                                    &m.src,
                                    std::slice::from_ref(&s),
                                    self.threads,
                                    &mut norm_scratch,
                                    &mut [buf],
                                ),
                                None => Err(Error::runtime(
                                    "decode plane buffer unexpectedly shared before fill",
                                )),
                            };
                            let one_ns = t_one.elapsed().as_nanos() as u64;
                            self.obs.decode_ns.record(one_ns);
                            if let Some(sp) = span.as_deref_mut() {
                                let end = sp.mark();
                                sp.add_phase(Phase::Decode, end.saturating_sub(one_ns), one_ns);
                            }
                            match single {
                                Ok(()) => {
                                    self.decoded_sections.fetch_add(1, Ordering::Relaxed);
                                    self.decoded_bytes
                                        .fetch_add(one.len() as u64 * 4, Ordering::Relaxed);
                                    self.cache
                                        .insert((m.id, si as u32, s as u32), Arc::clone(&one));
                                    planes[k] = Some(one);
                                }
                                Err(_) => {
                                    let t_salv = Instant::now();
                                    let (plane, stats) = engine
                                        .decode_shard_plane_salvage(&m.header, entry, &m.src, s)?;
                                    let salv_ns = t_salv.elapsed().as_nanos() as u64;
                                    if let Some(sp) = span.as_deref_mut() {
                                        let end = sp.mark();
                                        sp.add_phase(
                                            Phase::Salvage,
                                            end.saturating_sub(salv_ns),
                                            salv_ns,
                                        );
                                    }
                                    m.set_quarantined(si, s, stats);
                                    note_degraded(si, s, stats);
                                    planes[k] = Some(Arc::from(plane));
                                }
                            }
                        }
                    }
                }
            }
            // assemble through the same shared denorm op decompress_range
            // uses — bit-identity of cached and uncached reads is
            // structural, not a convention
            let lo_t = t0.max(entry.t0);
            let hi_t = t1.min(entry.t0 + entry.nt);
            for t in lo_t..hi_t {
                for (k, &s) in sel.iter().enumerate() {
                    let plane = planes[k]
                        .as_ref()
                        .ok_or_else(|| Error::runtime("decoded plane missing (store bug)"))?;
                    let (lo, hi) = m.header.ranges[s];
                    let src_off = (t - entry.t0) * npix;
                    let dst_off = ((t - t0) * nsel + k) * npix;
                    denorm_row_into(
                        &mut out[dst_off..dst_off + npix],
                        &plane[src_off..src_off + npix],
                        lo,
                        hi,
                    );
                }
            }
        }
        self.obs.probe_ns.record(probe_total_ns);
        let peak_workspace_bytes = out.len() * 4;
        degraded.sort_unstable();
        degraded.dedup();
        // one unstatable section bound makes the whole response bound
        // unstatable — never report a number that doesn't cover the data
        let degraded_bound = if bound_unknown { None } else { worst_bound };
        Ok(RangeDecode {
            t0,
            nt: t1 - t0,
            ny,
            nx,
            species: sel,
            mass: out,
            peak_workspace_bytes,
            degraded,
            degraded_bound,
        })
    }

    /// The executor handle this store decodes on.  Router replicas share
    /// one backend service by building siblings `with_handle` on the
    /// first replica's handle.
    pub fn exec_handle(&self) -> &ExecHandle {
        &self.handle
    }

    /// Whether every (shard, species) plane `q` touches is resident in
    /// the cache — a **side-effect-free** probe (`SectionCache::peek`:
    /// no counters, no recency refresh) the event loop uses to decide
    /// whether a query can run inline on the reactor thread.  Any
    /// resolution error reports cold; the real `query` call surfaces it.
    pub fn is_warm(&self, dataset: &str, q: &Query) -> bool {
        let Ok(m) = self.mount(dataset) else {
            return false;
        };
        let (nt, ns, _, _) = m.header.dims;
        let Ok(sel) = q.species.resolve(ns) else {
            return false;
        };
        let (t0, t1) = (q.time.start, q.time.end);
        if t0 >= t1 || t1 > nt {
            return false;
        }
        for (si, entry) in m.toc.iter().enumerate() {
            if entry.t0 >= t1 || entry.t0 + entry.nt <= t0 {
                continue;
            }
            for &s in &sel {
                if !self.cache.peek((m.id, si as u32, s as u32)) {
                    return false;
                }
            }
        }
        true
    }

    /// Counter snapshot across the cache, decode totals, and every
    /// mounted dataset's IO.
    pub fn stats(&self) -> StoreStats {
        StoreStats {
            queries: self.queries.load(Ordering::Relaxed),
            decoded_sections: self.decoded_sections.load(Ordering::Relaxed),
            decoded_bytes: self.decoded_bytes.load(Ordering::Relaxed),
            cache: self.cache.stats(),
            datasets: self.datasets(),
        }
    }
}
