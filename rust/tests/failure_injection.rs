//! Failure-injection tests: corrupted archives, truncated payloads, and
//! mismatched artifacts must yield errors, never panics or silent garbage.

use gbatc::archive::Archive;
use gbatc::compressor::SzArchive;

#[test]
fn archive_bit_flips_do_not_panic() {
    // a syntactically valid archive, corrupted at every byte position in a
    // stride, must either error out or produce a structurally valid result
    let basis = gbatc::gae::SpeciesBasis::from_mat(&gbatc::linalg::Mat::identity(4), 2);
    let a = Archive {
        tcn_used: false,
        dims: (4, 2, 5, 4),
        block: (4, 5, 4),
        latent_dim: 8,
        pressure: 1e5,
        ranges: vec![(0.0, 1.0); 2],
        latent_blob: vec![7; 64],
        species: vec![
            gbatc::archive::SpeciesSection { basis: basis.clone(), coeffs: vec![1, 2, 3] },
            gbatc::archive::SpeciesSection { basis, coeffs: vec![] },
        ],
        model_param_bytes: 10,
        nrmse_target: 1e-3,
    };
    let bytes = a.serialize();
    for i in (0..bytes.len()).step_by(3) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xFF;
        let _ = Archive::deserialize(&corrupt); // must not panic
    }
    for cut in [0, 1, 4, bytes.len() / 2, bytes.len() - 1] {
        assert!(Archive::deserialize(&bytes[..cut]).is_err());
    }
}

#[test]
fn sz_archive_corruption_does_not_panic() {
    let ds = gbatc::data::generate(gbatc::data::Profile::Tiny, 5);
    let szc = gbatc::compressor::SzCompressor::new(Default::default());
    let archive = szc.compress(&ds, 1e-2).unwrap();
    let bytes = archive.serialize();
    for i in (0..bytes.len().min(4096)).step_by(7) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x55;
        if let Ok(a) = SzArchive::deserialize(&corrupt) {
            let _ = szc.decompress(&a); // errors allowed, panics not
        }
    }
}

#[test]
fn missing_artifacts_is_clean_error() {
    let r = gbatc::runtime::ExecService::start("/nonexistent/dir", 2);
    assert!(r.is_err());
    let msg = format!("{}", r.err().unwrap());
    assert!(msg.contains("manifest") || msg.contains("artifact"), "{msg}");
}
