//! Failure-injection tests: corrupted archives, truncated payloads, and
//! mismatched artifacts must yield errors, never panics or silent garbage.
//!
//! The GBA2 tests drive damaged archives through the full serving stack
//! (`ArchiveStore::mount_bytes` + `query`, and a real loopback `/query`):
//! every outcome must be a typed error or a degraded-but-structurally-valid
//! response, quarantined sections must never be admitted to the
//! `SectionCache` (so the event loop's warm path can never serve salvage
//! inline), and healthy sections of a damaged archive must stay
//! bit-identical to a pristine decode.

use std::sync::Arc;

use gbatc::api::{Query, SpeciesSel};
use gbatc::archive::{Archive, Gba2Archive};
use gbatc::compressor::{CompressOptions, GbatcCompressor, SzArchive};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::serve::{QueryClient, QueryServer, ServerConfig};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

fn build_gba2(handle: &ExecHandle, nt: usize) -> Vec<u8> {
    let comp = GbatcCompressor::new(handle, 0, 0);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    comp.compress(&make_ds(nt, 9), &opts)
        .expect("compress")
        .archive
        .into_bytes()
}

fn store_cfg() -> StoreConfig {
    StoreConfig {
        threads: 2,
        cache_bytes: 32 << 20,
        cache_shards: 4,
        ..StoreConfig::default()
    }
}

/// Overwrite the first 8 bytes of (shard, species)'s section — the
/// serialized basis dims — so the section can neither decode strictly
/// nor salvage any coefficients.
fn wreck_section(bytes: &mut [u8], shard: usize, species: usize) {
    let toc = Gba2Archive::deserialize(bytes).expect("pristine archive").toc;
    let (off, len) = toc[shard].species[species];
    assert!(len >= 8, "section too small to target");
    for b in &mut bytes[off as usize..off as usize + 8] {
        *b = 0xFF;
    }
}

#[test]
fn archive_bit_flips_do_not_panic() {
    // a syntactically valid archive, corrupted at every byte position in a
    // stride, must either error out or produce a structurally valid result
    let basis = gbatc::gae::SpeciesBasis::from_mat(&gbatc::linalg::Mat::identity(4), 2);
    let a = Archive {
        tcn_used: false,
        dims: (4, 2, 5, 4),
        block: (4, 5, 4),
        latent_dim: 8,
        pressure: 1e5,
        ranges: vec![(0.0, 1.0); 2],
        latent_blob: vec![7; 64],
        species: vec![
            gbatc::archive::SpeciesSection { basis: basis.clone(), coeffs: vec![1, 2, 3] },
            gbatc::archive::SpeciesSection { basis, coeffs: vec![] },
        ],
        model_param_bytes: 10,
        nrmse_target: 1e-3,
    };
    let bytes = a.serialize();
    for i in (0..bytes.len()).step_by(3) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xFF;
        let _ = Archive::deserialize(&corrupt); // must not panic
    }
    for cut in [0, 1, 4, bytes.len() / 2, bytes.len() - 1] {
        assert!(Archive::deserialize(&bytes[..cut]).is_err());
    }
}

#[test]
fn sz_archive_corruption_does_not_panic() {
    let ds = gbatc::data::generate(gbatc::data::Profile::Tiny, 5);
    let szc = gbatc::compressor::SzCompressor::new(Default::default());
    let archive = szc.compress(&ds, 1e-2).unwrap();
    let bytes = archive.serialize();
    for i in (0..bytes.len().min(4096)).step_by(7) {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x55;
        if let Ok(a) = SzArchive::deserialize(&corrupt) {
            let _ = szc.decompress(&a); // errors allowed, panics not
        }
    }
}

#[test]
fn missing_artifacts_is_clean_error() {
    let r = gbatc::runtime::ExecService::start("/nonexistent/dir", 2);
    assert!(r.is_err());
    let msg = format!("{}", r.err().unwrap());
    assert!(msg.contains("manifest") || msg.contains("artifact"), "{msg}");
}

#[test]
fn gba2_corruption_sweep_is_typed_or_degraded_never_a_panic() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let nt = 16;
    let bytes = build_gba2(&handle, nt);
    let n_shards = Gba2Archive::deserialize(&bytes).unwrap().toc.len();
    let store = ArchiveStore::with_handle(&handle, store_cfg());
    let q = Query { time: 0..nt, species: SpeciesSel::All };
    let expect = nt * NS * NY * NX;

    // bit flips at a stride spanning header, TOC, latent planes, and
    // species sections: mount may reject (typed), a query may fail
    // (typed) or serve degraded — but an Ok response is always the full
    // window and only names real sections as damaged
    let step = (bytes.len() / 41).max(1);
    for (v, i) in (0..bytes.len()).step_by(step).enumerate() {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0xA5;
        let name = format!("flip{v}");
        if store.mount_bytes(&name, corrupt).is_err() {
            continue;
        }
        if let Ok(dec) = store.query(&name, &q) {
            assert_eq!(dec.mass.len(), expect, "byte {i}: short response");
            for &(sh, sp) in &dec.degraded {
                assert!(
                    sh < n_shards && sp < NS,
                    "byte {i}: bogus degraded section ({sh},{sp})"
                );
            }
        }
        store.unmount(&name).unwrap();
    }

    // truncations at every structural boundary class
    for cut in [0, 1, 7, 40, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1] {
        let name = format!("cut{cut}");
        if store.mount_bytes(&name, bytes[..cut].to_vec()).is_ok() {
            let _ = store.query(&name, &q); // typed error or degraded, never a panic
            store.unmount(&name).unwrap();
        }
    }
}

#[test]
fn quarantined_section_never_poisons_the_cache() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_gba2(&handle, 16);
    let mut sick = bytes.clone();
    wreck_section(&mut sick, 1, 2);

    let store = ArchiveStore::with_handle(&handle, store_cfg());
    store.mount_bytes("ok", bytes).unwrap();
    store.mount_bytes("sick", sick).unwrap();

    // t 4..8 is exactly shard 1 (kt window 4)
    let q = Query { time: 4..8, species: SpeciesSel::All };
    let good = store.query("ok", &q).unwrap();
    assert!(good.degraded.is_empty());
    assert_eq!(good.degraded_bound, None);

    let dec = store.query("sick", &q).unwrap();
    assert_eq!(dec.degraded, vec![(1, 2)]);
    assert!(
        dec.degraded_bound.is_none(),
        "nothing salvaged => no statable bound"
    );
    // healthy species of the damaged shard are bit-identical to the
    // pristine decode — the per-species retry isolates the rot
    let npix = NY * NX;
    for t in 0..4 {
        for s in (0..NS).filter(|&s| s != 2) {
            let r = (t * NS + s) * npix;
            assert!(
                dec.mass[r..r + npix]
                    .iter()
                    .zip(&good.mass[r..r + npix])
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "healthy species {s} differs at t {t}"
            );
        }
    }

    // the salvaged plane was never admitted to the cache: the healthy
    // subset is warm, any query touching (1, 2) is not — so the event
    // loop's inline warm path can never serve salvaged data
    let healthy = Query { time: 4..8, species: SpeciesSel::Indices(vec![0, 1, 3]) };
    assert!(store.is_warm("sick", &healthy), "healthy planes must be cached");
    assert!(!store.is_warm("sick", &q), "quarantined plane must stay cold");

    // a repeat query re-salvages (uncached) but decodes zero new
    // sections, and answers identically
    let before = store.stats().decoded_sections;
    let again = store.query("sick", &q).unwrap();
    assert_eq!(again.degraded, vec![(1, 2)]);
    assert_eq!(store.stats().decoded_sections, before);
    assert!(again
        .mass
        .iter()
        .zip(&dec.mass)
        .all(|(a, b)| a.to_bits() == b.to_bits()));
}

#[test]
fn degraded_serving_over_loopback_and_strict_503() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let mut sick = build_gba2(&handle, 8);
    wreck_section(&mut sick, 0, 1);

    let store = Arc::new(ArchiveStore::with_handle(&handle, store_cfg()));
    store.mount_bytes("hcci", sick).unwrap();
    let server = QueryServer::bind(
        Arc::clone(&store),
        "127.0.0.1:0",
        ServerConfig { workers: 2, queue: 8, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = server.addr().to_string();

    // a lax client gets salvage, flagged in the meta
    let lax = QueryClient::new(addr.clone());
    let dec = lax.query("hcci", Some(0), Some(4), "").unwrap();
    assert!(dec.degraded);
    assert!(
        dec.meta_json.contains("\"degraded_sections\":[[0,1]]"),
        "{}",
        dec.meta_json
    );
    assert_eq!(dec.mass.len(), 4 * NS * NY * NX);

    // a window clear of the rot keeps the exact healthy meta shape
    let clean = lax.query("hcci", Some(4), Some(8), "").unwrap();
    assert!(!clean.degraded);
    assert!(!clean.meta_json.contains("degraded"), "{}", clean.meta_json);

    // strict clients refuse salvage (503) but healthy windows still serve
    let strict = QueryClient::new(addr).strict(true);
    let err = strict.query("hcci", Some(0), Some(4), "").unwrap_err().to_string();
    assert!(err.contains("503") && err.contains("quarantined"), "{err}");
    let ok = strict.query("hcci", Some(4), Some(8), "").unwrap();
    assert!(!ok.degraded);
    server.shutdown();
}
