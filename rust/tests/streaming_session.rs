//! Streaming session API tests: a `CompressSession` fed timestep-by-
//! timestep must produce archives **byte-identical** to one-shot
//! `ShardEngine::compress` for the same options/policy (including
//! mixed-codec `--codec auto` plans), `ErrorPolicy::PerSpecies` budgets
//! must certify each species against its own target, and session misuse
//! must be typed errors.

use std::cell::RefCell;
use std::io::{Cursor, Seek, SeekFrom, Write};
use std::rc::Rc;

use gbatc::api::{
    ArchiveReader, CompressorBuilder, ErrorPolicy, FieldSpec, Query, SpeciesBudget, SpeciesSel,
};
use gbatc::archive::{Gba2Archive, StreamSink};
use gbatc::compressor::{CodecChoice, CompressOptions, Compressor, GbatcCompressor};
use gbatc::data::{generate, Dataset, Profile};
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::util::prop::{check, Arbitrary};
use gbatc::util::Prng;

const NS: usize = 2;
const NY: usize = 40;
const NX: usize = 40;

fn spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

/// Species 0 is a smooth low-frequency field (SZ-friendly); species 1 is
/// a high-frequency checkerboard under a drifting amplitude (leaves a
/// structured residual for the guarantee stage) — the same shape the
/// planner tests use, so `--codec auto` genuinely mixes codecs.
fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut rng = Prng::new(seed);
    let (p0, p1, p2) = (
        rng.uniform(0.04, 0.09) as f32,
        rng.uniform(0.2, 0.3) as f32,
        rng.uniform(0.01, 0.03) as f32,
    );
    let mut ds = Dataset::new(nt, NS, NY, NX);
    for t in 0..nt {
        for y in 0..NY {
            for x in 0..NX {
                let smooth =
                    0.5 + 0.3 * ((t as f32) * p1 + (y as f32) * p0 + (x as f32) * 0.05).sin();
                let sign = if (t + y + x) % 2 == 0 { 1.0f32 } else { -1.0 };
                let amp = 0.2 + 0.05 * ((t as f32) * 0.3 + (y as f32) * p2).cos();
                let i0 = ds.idx(t, 0, y, x);
                ds.mass[i0] = smooth;
                let i1 = ds.idx(t, 1, y, x);
                ds.mass[i1] = 0.5 + sign * amp;
            }
        }
    }
    ds
}

fn session_bytes(
    handle: &ExecHandle,
    ds: &Dataset,
    opts: &CompressOptions,
    policy: &ErrorPolicy,
) -> (Vec<u8>, usize) {
    let mut session = CompressorBuilder::from_options(opts)
        .error_policy(policy.clone())
        .session_on(handle, 0, 0, FieldSpec::from_dataset(ds), Cursor::new(Vec::new()))
        .expect("open session");
    // strictly one timestep at a time — the live-solver call pattern
    let stride = ds.ns * ds.ny * ds.nx;
    for t in 0..ds.nt {
        session
            .push_timestep(&ds.mass[t * stride..(t + 1) * stride])
            .expect("push");
        assert_eq!(session.timesteps_pushed(), t + 1);
    }
    let (report, sink) = session.finish_into().expect("finish");
    let bytes = sink.into_inner();
    assert_eq!(report.archive_bytes as usize, bytes.len());
    (bytes, report.peak_workspace_bytes)
}

#[derive(Clone, Debug)]
struct SessionCase {
    seed: u64,
    nt: usize,
    kt_window: usize,
    codec: CodecChoice,
    nrmse: f64,
}

impl Arbitrary for SessionCase {
    fn generate(rng: &mut Prng) -> Self {
        let codec = [
            CodecChoice::Gbatc,
            CodecChoice::Auto,
            CodecChoice::Sz,
            CodecChoice::Dense,
        ][rng.index(4)];
        SessionCase {
            seed: rng.next_u64(),
            nt: [8, 12, 16][rng.index(3)],
            kt_window: [4, 8][rng.index(2)],
            codec,
            nrmse: [1e-2, 1e-3][rng.index(2)],
        }
    }

    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.nt > 8 {
            let mut c = self.clone();
            c.nt = 8;
            out.push(c);
        }
        if self.codec != CodecChoice::Gbatc {
            let mut c = self.clone();
            c.codec = CodecChoice::Gbatc;
            out.push(c);
        }
        out
    }
}

/// The acceptance-criterion property: streamed == one-shot, byte for
/// byte, across codec policies (including deferred `auto` planning).
#[test]
fn prop_session_byte_identical_to_one_shot() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    check::<SessionCase, _>(23, 10, |case| {
        let ds = make_ds(case.nt, case.seed);
        let opts = CompressOptions {
            nrmse_target: case.nrmse,
            kt_window: case.kt_window,
            threads: 2,
            shard_workers: 2,
            codec: case.codec,
            ..Default::default()
        };
        let comp = GbatcCompressor::new(&handle, 0, 0);
        let one_shot = comp.compress(&ds, &opts).expect("one-shot").archive;
        let (streamed, _) =
            session_bytes(&handle, &ds, &opts, &ErrorPolicy::Uniform(case.nrmse));
        streamed == one_shot.bytes
    });
}

/// The `Compressor` trait's `compress_bytes` is now a session adapter —
/// it must keep producing the engine's exact bytes.
#[test]
fn compress_bytes_adapter_matches_engine() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(8, 5);
    for codec in [CodecChoice::Gbatc, CodecChoice::Auto] {
        let opts = CompressOptions {
            nrmse_target: 1e-3,
            kt_window: 4,
            codec,
            ..Default::default()
        };
        let comp = GbatcCompressor::new(&handle, 0, 0).with_options(opts.clone());
        let report = comp.compress(&ds, &opts).unwrap();
        let bytes = comp.compress_bytes(&ds, 1e-3).unwrap();
        assert_eq!(bytes, report.archive.bytes, "{codec:?}");
    }
}

/// Per-species NRMSE over the denormalized field (range-normalized, the
/// certification metric).
fn per_species_nrmse(ds: &Dataset, recon: &[f32]) -> Vec<f64> {
    let npix = ds.ny * ds.nx;
    let ranges = ds.species_ranges();
    (0..ds.ns)
        .map(|s| {
            let mut se = 0.0f64;
            let mut n = 0usize;
            for t in 0..ds.nt {
                let off = (t * ds.ns + s) * npix;
                for i in off..off + npix {
                    let e = (ds.mass[i] - recon[i]) as f64;
                    se += e * e;
                    n += 1;
                }
            }
            let range = (ranges[s].1 - ranges[s].0).max(1e-30) as f64;
            (se / n as f64).sqrt() / range
        })
        .collect()
}

/// `ErrorPolicy::PerSpecies`: each species is certified against its own
/// budget, the session stays byte-identical to one-shot under the same
/// policy, and the loosest target lands in the header.
#[test]
fn per_species_budgets_certify_each_species() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(16, 9);
    let targets = [5e-3, 2e-4];
    let policy = ErrorPolicy::PerSpecies(vec![
        SpeciesBudget::index(0, targets[0]),
        SpeciesBudget::index(1, targets[1]),
    ]);
    for codec in [CodecChoice::Gbatc, CodecChoice::Auto] {
        let opts = CompressOptions {
            nrmse_target: 1e-3, // ignored: the policy wins
            kt_window: 8,
            codec,
            ..Default::default()
        };
        let comp = GbatcCompressor::new(&handle, 0, 0);
        let report = comp.compress_with_policy(&ds, &opts, &policy).unwrap();
        // the header records the loosest target for display
        assert_eq!(report.archive.header.nrmse_target, targets[0]);
        let recon = comp.decompress(&report.archive, 0).unwrap();
        let per = per_species_nrmse(&ds, &recon);
        for (s, (&err, &target)) in per.iter().zip(&targets).enumerate() {
            assert!(
                err <= target * 1.05,
                "{codec:?} species {s}: NRMSE {err:.3e} exceeds its budget {target:.1e}"
            );
        }
        // streamed session under the same policy: byte-identical
        let (streamed, _) = session_bytes(&handle, &ds, &opts, &policy);
        assert_eq!(streamed, report.archive.bytes, "{codec:?}");
    }
}

/// Name-addressed budgets on the full 58-species mechanism: the tight
/// species obeys its tighter bound.
#[test]
fn named_budgets_resolve_through_the_mechanism() {
    let ds = generate(Profile::Tiny, 31);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let policy = ErrorPolicy::PerSpecies(vec![
        SpeciesBudget::all(3e-3),
        SpeciesBudget::name("OH", 3e-4),
    ]);
    let opts = CompressOptions::default();
    let report = comp.compress_with_policy(&ds, &opts, &policy).unwrap();
    let recon = comp.decompress(&report.archive, 0).unwrap();
    let per = per_species_nrmse(&ds, &recon);
    let oh = gbatc::chem::resolve_species("OH").unwrap();
    assert!(per[oh] <= 3e-4 * 1.05, "OH NRMSE {:.3e}", per[oh]);
    for (s, &err) in per.iter().enumerate() {
        assert!(err <= 3e-3 * 1.05, "species {s}: NRMSE {err:.3e}");
    }
    // an unknown name in a budget is a typed, listing error
    let bad = ErrorPolicy::PerSpecies(vec![SpeciesBudget::name("unobtainium", 1e-3)]);
    let err = comp
        .compress_with_policy(&ds, &opts, &bad)
        .unwrap_err()
        .to_string();
    assert!(err.contains("available"), "{err}");
}

/// Session peak workspace is the one-shot shard workspace plus exactly
/// one window buffer — O(shard), never O(field).
#[test]
fn session_workspace_bounded_by_one_window() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(16, 3);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        threads: 2,
        shard_workers: 1,
        ..Default::default()
    };
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let one_shot_peak = comp.compress(&ds, &opts).unwrap().peak_workspace_bytes;
    let (_, session_peak) =
        session_bytes(&handle, &ds, &opts, &ErrorPolicy::Uniform(1e-3));
    let window_bytes = opts.kt_window * ds.ns * ds.ny * ds.nx * 4;
    assert!(
        session_peak >= one_shot_peak && session_peak <= one_shot_peak + window_bytes,
        "session peak {session_peak} vs one-shot {one_shot_peak} + window {window_bytes}"
    );
}

/// Session misuse is typed errors, never a corrupt archive.
#[test]
fn session_misuse_is_rejected() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(8, 7);
    let opts = CompressOptions {
        kt_window: 4,
        ..Default::default()
    };
    let open = || {
        CompressorBuilder::from_options(&opts)
            .session_on(
                &handle,
                0,
                0,
                FieldSpec::from_dataset(&ds),
                Cursor::new(Vec::new()),
            )
            .unwrap()
    };
    let stride = ds.ns * ds.ny * ds.nx;

    // wrong frame length
    let mut s = open();
    assert!(s.push_timestep(&ds.mass[..stride - 1]).is_err());

    // finishing before every declared timestep arrived
    let mut s = open();
    s.push_timestep(&ds.mass[..stride]).unwrap();
    assert!(s.finish().is_err());

    // pushing past the declared run length
    let mut s = open();
    s.push_dataset(&ds).unwrap();
    assert!(s.push_timestep(&ds.mass[..stride]).is_err());

    // config errors surface at open, before any timestep is accepted
    let bad = CompressOptions {
        kt_window: 3, // not a multiple of block kt
        ..Default::default()
    };
    assert!(CompressorBuilder::from_options(&bad)
        .session_on(
            &handle,
            0,
            0,
            FieldSpec::from_dataset(&ds),
            Cursor::new(Vec::new()),
        )
        .is_err());
    let bad = ErrorPolicy::Uniform(-1.0);
    assert!(CompressorBuilder::from_options(&opts)
        .error_policy(bad)
        .session_on(
            &handle,
            0,
            0,
            FieldSpec::from_dataset(&ds),
            Cursor::new(Vec::new()),
        )
        .is_err());
    let bad_ranges = FieldSpec {
        ranges: vec![(0.0, f32::NAN); ds.ns],
        ..FieldSpec::from_dataset(&ds)
    };
    assert!(CompressorBuilder::from_options(&opts)
        .session_on(&handle, 0, 0, bad_ranges, Cursor::new(Vec::new()))
        .is_err());
}

/// A sink that errors once more than `budget` bytes ever landed in it —
/// drives the failed-flush path.
struct FailingSink {
    inner: Cursor<Vec<u8>>,
    budget: usize,
}

impl Write for FailingSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.inner.position() as usize + buf.len() > self.budget {
            return Err(std::io::Error::other("sink full"));
        }
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

impl Seek for FailingSink {
    fn seek(&mut self, pos: SeekFrom) -> std::io::Result<u64> {
        self.inner.seek(pos)
    }
}

/// A failed window flush poisons the session: every later call is a
/// typed error, never a panic into the half-written stream.
#[test]
fn failed_flush_poisons_the_session() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(8, 13);
    let opts = CompressOptions {
        kt_window: 4,
        ..Default::default()
    };
    // large enough for the reserved header + TOC region, far too small
    // for the first shard's payload
    let sink = FailingSink {
        inner: Cursor::new(Vec::new()),
        budget: 300,
    };
    let mut s = CompressorBuilder::from_options(&opts)
        .session_on(&handle, 0, 0, FieldSpec::from_dataset(&ds), sink)
        .unwrap();
    let stride = ds.ns * ds.ny * ds.nx;
    let mut failed = false;
    for t in 0..ds.nt {
        if s.push_timestep(&ds.mass[t * stride..(t + 1) * stride]).is_err() {
            failed = true;
            break;
        }
    }
    assert!(failed, "the failing sink never surfaced an error");
    assert!(s.push_timestep(&ds.mass[..stride]).is_err());
    assert!(s.finish().is_err());
}

/// A sink that models a crash: writes land until `budget` bytes, the
/// write that crosses the line is *torn* (its prefix lands — exactly
/// what a killed process leaves on disk), and everything after errors.
/// The buffer is shared so the test can read the surviving bytes after
/// the poisoned session is dropped.
struct TornSink {
    buf: Rc<RefCell<Vec<u8>>>,
    pos: usize,
    budget: usize,
    dead: bool,
}

impl TornSink {
    fn new(budget: usize) -> (TornSink, Rc<RefCell<Vec<u8>>>) {
        let buf = Rc::new(RefCell::new(Vec::new()));
        (
            TornSink {
                buf: Rc::clone(&buf),
                pos: 0,
                budget,
                dead: false,
            },
            buf,
        )
    }

    fn land(&mut self, bytes: &[u8]) {
        let mut data = self.buf.borrow_mut();
        let end = self.pos + bytes.len();
        if data.len() < end {
            data.resize(end, 0);
        }
        data[self.pos..end].copy_from_slice(bytes);
        self.pos = end;
    }
}

impl Write for TornSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.dead {
            return Err(std::io::Error::other("sink is dead"));
        }
        if self.pos + buf.len() > self.budget {
            let keep = self.budget.saturating_sub(self.pos);
            self.land(&buf[..keep]);
            self.dead = true;
            return Err(std::io::Error::other("killed mid-write"));
        }
        self.land(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl Seek for TornSink {
    fn seek(&mut self, pos: SeekFrom) -> std::io::Result<u64> {
        let len = self.buf.borrow().len() as i64;
        let target = match pos {
            SeekFrom::Start(p) => p as i64,
            SeekFrom::End(d) => len + d,
            SeekFrom::Current(d) => self.pos as i64 + d,
        };
        if target < 0 {
            return Err(std::io::Error::other("seek before start"));
        }
        self.pos = target as usize;
        Ok(self.pos as u64)
    }
}

// everything `land`ed counts as durable in this crash model; truncation
// is never needed before the tear (finish only truncates, and a torn
// session never reaches finish)
impl StreamSink for TornSink {}

/// The crash-consistency acceptance property: kill the writer at byte
/// budgets bracketing **every shard boundary** (torn payload tail,
/// payload-durable-but-uncommitted, torn trailer/next payload), resume
/// from the surviving bytes, replay the run — the sealed archive is
/// byte-identical to the uninterrupted one at every kill point.
#[test]
fn prop_kill_at_every_shard_boundary_resumes_byte_identical() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(12, 21);
    let stride = ds.ns * ds.ny * ds.nx;
    for codec in [CodecChoice::Gbatc, CodecChoice::Sz] {
        let opts = CompressOptions {
            nrmse_target: 1e-2,
            kt_window: 4,
            threads: 2,
            shard_workers: 2,
            codec,
            ..Default::default()
        };
        let policy = ErrorPolicy::Uniform(1e-2);
        let (reference, _) = session_bytes(&handle, &ds, &opts, &policy);
        // the sealed TOC gives every shard's payload end; the unsealed
        // stream places payloads at the same offsets (the journal lives
        // inside the reserved header region)
        let toc = Gba2Archive::deserialize(&reference).expect("reference parses").toc;
        let mut budgets: Vec<usize> = Vec::new();
        for e in &toc {
            let end = (e.shard.0 + e.shard.1) as usize;
            for off in [-3i64, 0, 9] {
                budgets.push((end as i64 + off).max(1) as usize);
            }
        }
        for &budget in &budgets {
            let (sink, shared) = TornSink::new(budget);
            let mut s = CompressorBuilder::from_options(&opts)
                .error_policy(policy.clone())
                .session_on(&handle, 0, 0, FieldSpec::from_dataset(&ds), sink)
                .expect("open session");
            let mut killed = false;
            for t in 0..ds.nt {
                if s.push_timestep(&ds.mass[t * stride..(t + 1) * stride]).is_err() {
                    killed = true;
                    break;
                }
            }
            let bytes = if killed {
                drop(s);
                // resume from exactly what survived the crash, replay
                // the whole run (resumed sessions skip recovered frames)
                let survivor = Cursor::new(shared.borrow().clone());
                let (mut r, rep) = CompressorBuilder::from_options(&opts)
                    .error_policy(policy.clone())
                    .resume_session_on(&handle, 0, 0, FieldSpec::from_dataset(&ds), survivor)
                    .expect("resume from torn stream");
                assert_eq!(r.timesteps_skipped(), rep.timesteps, "kill at {budget}");
                for t in 0..ds.nt {
                    r.push_timestep(&ds.mass[t * stride..(t + 1) * stride])
                        .expect("replay push");
                }
                let (_, sink) = r.finish_into().expect("resumed finish");
                sink.into_inner()
            } else {
                assert!(
                    budget >= reference.len(),
                    "codec {codec:?}: budget {budget} inside the stream never killed it"
                );
                s.finish_into().expect("uninterrupted finish");
                shared.borrow().clone()
            };
            assert_eq!(
                bytes, reference,
                "codec {codec:?}, kill at byte {budget}: resumed archive diverged"
            );
        }
    }
}

/// The typed egress: `ArchiveReader::query` over a streamed archive is
/// bit-identical to slicing the full decode, and species resolve by
/// name.
#[test]
fn archive_reader_query_matches_full_decode() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let ds = make_ds(16, 11);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        codec: CodecChoice::Auto,
        ..Default::default()
    };
    let (bytes, _) = session_bytes(&handle, &ds, &opts, &ErrorPolicy::Uniform(1e-3));
    let reader = ArchiveReader::with_handle(&handle, bytes, 0).unwrap();
    assert_eq!(reader.n_shards(), 4);
    let full = reader.decompress_all().unwrap();

    reader.reset_io_stats();
    let q = Query {
        time: 5..9,
        species: SpeciesSel::Indices(vec![1]),
    };
    let dec = reader.query(&q).unwrap();
    assert_eq!(dec.species, vec![1]);
    let npix = ds.ny * ds.nx;
    for t in 5..9usize {
        for p in 0..npix {
            let a = full[(t * NS + 1) * npix + p];
            let b = dec.mass[(t - 5) * npix + p];
            assert_eq!(a.to_bits(), b.to_bits(), "t={t} p={p}");
        }
    }
    // partial reads must touch strictly fewer bytes than the archive
    assert!(reader.bytes_read() < reader.archive_bytes());
    // out-of-range / zero selections are typed errors
    assert!(reader.query(&Query::window(9..9)).is_err());
    assert!(reader
        .query(&Query {
            time: 0..1,
            species: SpeciesSel::Indices(vec![NS]),
        })
        .is_err());
}
