//! Integration tests over the full L3 stack: runtime service, shard
//! pipelines, Algorithm 1, archive round-trip, and the SZ baseline.
//!
//! Tests in the `aot` half exercise the real AOT artifacts and skip when
//! `make artifacts` has not run; the `reference` half runs the identical
//! request path on the pure-Rust backend, so the guarantees are verified
//! in the offline image too.

use gbatc::archive::Gba2Archive;
use gbatc::compressor::{CompressOptions, GbatcCompressor, SzCompressOptions, SzCompressor};
use gbatc::config::Manifest;
use gbatc::data::{generate, io, Profile};
use gbatc::metrics;
use gbatc::runtime::{ExecService, RuntimeSpec};

fn artifacts_dir() -> String {
    std::env::var("GBATC_ARTIFACTS").unwrap_or_else(|_| {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    })
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir()).join("manifest.txt").exists()
}

/// Mean per-species NRMSE between two mass arrays in `[T,S,Y,X]` layout.
fn mean_species_nrmse(
    orig: &[f32],
    recon: &[f32],
    dims: (usize, usize, usize, usize),
) -> (Vec<f64>, f64) {
    let (nt, ns, ny, nx) = dims;
    let npix = ny * nx;
    let mut per = Vec::with_capacity(ns);
    for s in 0..ns {
        let mut o = Vec::with_capacity(nt * npix);
        let mut r = Vec::with_capacity(nt * npix);
        for t in 0..nt {
            let off = (t * ns + s) * npix;
            o.extend_from_slice(&orig[off..off + npix]);
            r.extend_from_slice(&recon[off..off + npix]);
        }
        per.push(metrics::nrmse(&o, &r));
    }
    let mean = per.iter().sum::<f64>() / ns as f64;
    (per, mean)
}

#[test]
fn gbatc_end_to_end_respects_nrmse_target() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let ds = generate(Profile::Tiny, 77);
    let service = ExecService::start(&artifacts_dir(), 4).unwrap();
    let handle = service.handle();
    let manifest = Manifest::load(format!("{}/manifest.txt", artifacts_dir())).unwrap();
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);

    let target = 1e-3;
    let opts = CompressOptions {
        nrmse_target: target,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    // Algorithm 1 invariant: every block within tau
    assert!(
        report.max_block_residual <= report.tau + 1e-9,
        "residual {} > tau {}",
        report.max_block_residual,
        report.tau
    );
    let cr = report.archive.compression_ratio();
    assert!(cr > 1.0, "CR {cr} <= 1");

    // full round trip through bytes (GBA2)
    let bytes = report.archive.serialize();
    let archive = Gba2Archive::deserialize(&bytes).unwrap();
    let mass = comp.decompress(&archive, 0).unwrap();
    assert_eq!(mass.len(), ds.mass.len());

    let (_per, mean) = mean_species_nrmse(&ds.mass, &mass, (ds.nt, ds.ns, ds.ny, ds.nx));
    // per-block l2 bound implies per-species NRMSE <= target (up to fp)
    assert!(
        mean <= target * 1.05,
        "mean NRMSE {mean} exceeds target {target}"
    );
    println!("GBATC tiny: CR {cr:.1}, mean NRMSE {mean:.3e}");
}

#[test]
fn gba_without_tcn_also_bounded() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let ds = generate(Profile::Tiny, 78);
    let service = ExecService::start(&artifacts_dir(), 4).unwrap();
    let handle = service.handle();
    let manifest = Manifest::load(format!("{}/manifest.txt", artifacts_dir())).unwrap();
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);
    let opts = CompressOptions {
        nrmse_target: 3e-3,
        use_tcn: false,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert!(!report.archive.header.tcn_used);
    let mass = comp.decompress(&report.archive, 0).unwrap();
    let (_, mean) = mean_species_nrmse(&ds.mass, &mass, (ds.nt, ds.ns, ds.ny, ds.nx));
    assert!(mean <= 3e-3 * 1.05, "GBA mean NRMSE {mean}");
}

#[test]
fn tighter_target_lowers_cr() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let ds = generate(Profile::Tiny, 79);
    let service = ExecService::start(&artifacts_dir(), 4).unwrap();
    let handle = service.handle();
    let manifest = Manifest::load(format!("{}/manifest.txt", artifacts_dir())).unwrap();
    let comp = GbatcCompressor::new(&handle, manifest.decoder_params, manifest.tcn_params);
    let mut crs = Vec::new();
    for target in [1e-2, 1e-3, 3e-4] {
        let opts = CompressOptions {
            nrmse_target: target,
            ..Default::default()
        };
        let report = comp.compress(&ds, &opts).unwrap();
        crs.push(report.archive.compression_ratio());
    }
    assert!(
        crs[0] >= crs[1] && crs[1] >= crs[2],
        "CRs not monotone: {crs:?}"
    );
}

#[test]
fn encoder_produces_informative_latents() {
    // Regression test for the elided-constants bug: HLO text prints large
    // weights as `constant({...})`, which silently zeroes them.  With dead
    // weights the encoder returns all-zero latents and the PCA guarantee
    // silently absorbs the entire signal — so assert the latent plane
    // actually carries information.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let ds = generate(Profile::Tiny, 81);
    let service = ExecService::start(&artifacts_dir(), 4).unwrap();
    let handle = service.handle();
    let spec = handle.spec();
    let grid = gbatc::data::blocks::BlockGrid::for_dataset(
        &ds,
        gbatc::data::blocks::BlockShape::default(),
    )
    .unwrap();
    let ranges = ds.species_ranges();
    let norm = gbatc::compressor::gba::normalize_mass(&ds, &ranges, 4);
    let n = spec.batch.min(grid.n_blocks());
    let batch = gbatc::coordinator::batcher::gather_batch(&grid, &norm, 0, n);
    let z = handle.encode(batch.clone(), n).unwrap();
    let mean = z.iter().map(|&v| v as f64).sum::<f64>() / z.len() as f64;
    let var = z
        .iter()
        .map(|&v| (v as f64 - mean) * (v as f64 - mean))
        .sum::<f64>()
        / z.len() as f64;
    assert!(var > 1e-6, "latents are (near-)constant: var {var}");

    // and the decoder round-trip must beat the all-zeros baseline clearly
    let recon = handle.decode(z, n).unwrap();
    let mse: f64 = batch
        .iter()
        .zip(&recon)
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / batch.len() as f64;
    let zero_mse: f64 =
        batch.iter().map(|&a| (a as f64).powi(2)).sum::<f64>() / batch.len() as f64;
    assert!(
        mse < 0.25 * zero_mse,
        "AE no better than zeros: {mse:.3e} vs {zero_mse:.3e}"
    );
}

#[test]
fn reference_end_to_end_respects_nrmse_target() {
    // Same invariants as the AOT test, but on the pure-Rust backend — the
    // guarantee stage makes the error bound independent of model quality,
    // so this runs (and must pass) with no artifacts at all.
    let ds = generate(Profile::Tiny, 83);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);

    let target = 1e-3;
    let opts = CompressOptions {
        nrmse_target: target,
        kt_window: 4,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert_eq!(report.n_shards, 2);
    assert!(
        report.max_block_residual <= report.tau + 1e-9,
        "residual {} > tau {}",
        report.max_block_residual,
        report.tau
    );
    let bytes = report.archive.serialize();
    let archive = Gba2Archive::deserialize(&bytes).unwrap();
    let mass = comp.decompress(&archive, 0).unwrap();
    assert_eq!(mass.len(), ds.mass.len());
    let (per, mean) = mean_species_nrmse(&ds.mass, &mass, (ds.nt, ds.ns, ds.ny, ds.nx));
    assert!(
        per.iter().all(|&e| e <= target * 1.05),
        "a species exceeded the target: {per:?}"
    );
    assert!(mean <= target * 1.05, "mean NRMSE {mean}");
}

#[test]
fn reference_single_window_round_trips() {
    // kt_window >= nt collapses to one shard and must still round-trip
    let ds = generate(Profile::Tiny, 84);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let opts = CompressOptions {
        nrmse_target: 3e-3,
        kt_window: 8,
        use_tcn: false,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert_eq!(report.n_shards, 1);
    let mass = comp.decompress(&report.archive, 0).unwrap();
    let (_, mean) = mean_species_nrmse(&ds.mass, &mass, (ds.nt, ds.ns, ds.ny, ds.nx));
    assert!(mean <= 3e-3 * 1.05, "mean NRMSE {mean}");
}

/// The hot-path overhaul's determinism contract: thread counts, worker
/// counts, and the parallel PCA must not change a single archive byte.
/// (Every parallel reduction keeps its sequential order — see
/// `Pca::fit_threads` and the guarantee GEMM.)
#[test]
fn archive_bytes_independent_of_thread_counts() {
    let ds = generate(Profile::Tiny, 85);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);

    for codec in [
        gbatc::compressor::CodecChoice::Gbatc,
        gbatc::compressor::CodecChoice::Auto,
    ] {
        let mut first: Option<Vec<u8>> = None;
        for (threads, shard_workers) in [(1usize, 1usize), (2, 1), (4, 2), (8, 2)] {
            let opts = CompressOptions {
                nrmse_target: 1e-3,
                kt_window: 4,
                threads,
                shard_workers,
                codec,
                ..Default::default()
            };
            let report = comp.compress(&ds, &opts).unwrap();
            let bytes = report.archive.serialize();
            match &first {
                None => first = Some(bytes),
                Some(r) => assert_eq!(
                    r, &bytes,
                    "{codec:?} archive changed with threads={threads} workers={shard_workers}"
                ),
            }
        }
    }
}

#[test]
fn sz_baseline_same_data() {
    let ds = generate(Profile::Tiny, 77);
    let szc = SzCompressor::new(SzCompressOptions::default());
    let archive = szc.compress(&ds, 1e-3).unwrap();
    let mass = szc.decompress(&archive).unwrap();
    let (_, mean) = mean_species_nrmse(&ds.mass, &mass, (ds.nt, ds.ns, ds.ny, ds.nx));
    assert!(mean <= 1.2e-3, "SZ mean NRMSE {mean}");
}

#[test]
fn dataset_file_roundtrip_through_cli_formats() {
    let ds = generate(Profile::Tiny, 80);
    let dir = std::env::temp_dir();
    let p = dir.join("gbatc_it_ds.bin");
    io::write_dataset(&p, &ds).unwrap();
    let ds2 = io::read_dataset(&p).unwrap();
    assert_eq!(ds.mass, ds2.mass);
    std::fs::remove_file(p).ok();
}
