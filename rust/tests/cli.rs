//! CLI integration: drives the `gbatc` binary end-to-end through
//! gen-data -> compress -> decompress -> evaluate -> info -> sz.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_gbatc")
}

fn artifacts() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(bin())
        .args(args)
        .output()
        .expect("spawn gbatc");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn cli_help_and_unknown_command() {
    let (ok, text) = run(&["help"]);
    assert!(ok, "{text}");
    assert!(text.contains("compress"));
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown command"));
}

#[test]
fn cli_full_pipeline() {
    if !artifacts().join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = std::env::temp_dir().join("gbatc_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ds = dir.join("ds.sdf");
    let gba = dir.join("ds.gba");
    let rec = dir.join("rec.sdf");
    let szf = dir.join("ds.szf");
    let art = artifacts();
    let art = art.to_str().unwrap();

    let (ok, text) = run(&[
        "gen-data", "--out", ds.to_str().unwrap(), "--profile", "tiny", "--seed", "3",
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "compress", "--input", ds.to_str().unwrap(), "--output", gba.to_str().unwrap(),
        "--nrmse", "1e-3", "--artifacts", art,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("CR"));

    let (ok, text) = run(&[
        "decompress", "--input", gba.to_str().unwrap(), "--output", rec.to_str().unwrap(),
        "--temp-from", ds.to_str().unwrap(), "--artifacts", art,
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "evaluate", "--orig", ds.to_str().unwrap(), "--recon", rec.to_str().unwrap(),
        "--species", "C2H3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("mean NRMSE"), "{text}");
    // parse the mean NRMSE and check the bound
    let mean: f64 = text
        .lines()
        .find(|l| l.contains("mean NRMSE"))
        .and_then(|l| l.split(':').nth(1))
        .and_then(|v| v.trim().parse().ok())
        .expect("parse NRMSE");
    assert!(mean <= 1.05e-3, "CLI round trip NRMSE {mean}");

    let (ok, text) = run(&["info", "--archive", gba.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("GBATC archive"));

    let (ok, text) = run(&[
        "sz", "--input", ds.to_str().unwrap(), "--output", szf.to_str().unwrap(),
        "--nrmse", "1e-3",
    ]);
    assert!(ok, "{text}");
    let (ok, text) = run(&["info", "--archive", szf.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("SZ archive"));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_missing_args_are_clean_errors() {
    let (ok, text) = run(&["compress", "--input", "x"]);
    assert!(!ok);
    assert!(text.contains("--output"), "{text}");
    let (ok, _) = run(&["evaluate"]);
    assert!(!ok);
}

/// The whole pipeline on the pure-Rust reference backend — no artifacts
/// needed, so this runs in the offline image: gen-data -> sharded compress
/// -> inspect (TOC) -> decompress -> evaluate -> extract (partial decode,
/// verified bit-identical against the full reconstruction).
#[test]
fn cli_reference_pipeline_with_partial_decode() {
    let dir = std::env::temp_dir().join("gbatc_cli_ref_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ds = dir.join("ds.sdf");
    let gba = dir.join("ds.gba2");
    let rec = dir.join("rec.sdf");
    let ext = dir.join("win.sdf");

    let (ok, text) = run(&[
        "gen-data", "--out", ds.to_str().unwrap(), "--profile", "tiny", "--seed", "5",
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--nrmse", "1e-3", "--kt-window", "4",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("CR"), "{text}");
    assert!(text.contains("2 shards"), "{text}");
    // per-stage wall-time attribution is part of the compress report
    assert!(text.contains("stages: pca fit"), "{text}");
    assert!(text.contains("guarantee loop"), "{text}");

    let (ok, text) = run(&["inspect", "--archive", gba.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("GBA2"), "{text}");
    assert!(text.contains("shard"), "{text}");

    // --stats reopens through the metered reader and reports classified
    // open IO (header/TOC reads must now be counted, not just payload)
    let (ok, text) = run(&["inspect", "--archive", gba.to_str().unwrap(), "--stats"]);
    assert!(ok, "{text}");
    assert!(text.contains("open IO: toc"), "{text}");
    assert!(text.contains("payload 0 B"), "{text}");

    let (ok, text) = run(&[
        "decompress", "--reference", "--input", gba.to_str().unwrap(),
        "--output", rec.to_str().unwrap(), "--temp-from", ds.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "evaluate", "--orig", ds.to_str().unwrap(), "--recon", rec.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");
    let mean: f64 = text
        .lines()
        .find(|l| l.contains("mean NRMSE"))
        .and_then(|l| l.split(':').nth(1))
        .and_then(|v| v.trim().parse().ok())
        .expect("parse NRMSE");
    assert!(mean <= 1.05e-3, "reference round trip NRMSE {mean}");

    let (ok, text) = run(&[
        "extract", "--reference", "--input", gba.to_str().unwrap(),
        "--output", ext.to_str().unwrap(), "--t0", "4", "--t1", "8",
        "--species", "C2H3",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("archive bytes"), "{text}");

    // the extracted window must bit-equal the same slice of the full decode
    let full = gbatc::data::io::read_dataset(&rec).unwrap();
    let part = gbatc::data::io::read_dataset(&ext).unwrap();
    let s = gbatc::chem::index_of("C2H3").unwrap();
    assert_eq!((part.nt, part.ns, part.ny, part.nx), (4, 1, full.ny, full.nx));
    let npix = full.ny * full.nx;
    for t in 4..8usize {
        for p in 0..npix {
            let a = full.mass[(t * full.ns + s) * npix + p];
            let b = part.mass[(t - 4) * npix + p];
            assert_eq!(a.to_bits(), b.to_bits(), "t={t} p={p}");
        }
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// `--codec auto` end to end on the reference backend: the planner
/// archive inspects with per-section codec tags + per-codec byte totals,
/// extracts bit-identically, and config errors are typed and early.
#[test]
fn cli_codec_planner_pipeline() {
    let dir = std::env::temp_dir().join("gbatc_cli_codec_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ds = dir.join("ds.sdf");
    let gba = dir.join("ds.auto.gba2");
    let rec = dir.join("rec.sdf");
    let ext = dir.join("win.sdf");

    let (ok, text) = run(&[
        "gen-data", "--out", ds.to_str().unwrap(), "--profile", "tiny", "--seed", "9",
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--nrmse", "1e-3", "--kt-window", "4",
        "--codec", "auto",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("per-codec"), "{text}");

    let (ok, text) = run(&["inspect", "--archive", gba.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("codecs"), "{text}");
    assert!(text.contains("per-codec"), "{text}");

    // an all-SZ archive gives a *deterministic* per-codec totals line:
    // zero GBATC sections, every section tagged SZ
    let sz_gba = dir.join("ds.sz.gba2");
    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", sz_gba.to_str().unwrap(), "--nrmse", "1e-3", "--kt-window", "4",
        "--codec", "sz",
    ]);
    assert!(ok, "{text}");
    let (ok, text) = run(&["inspect", "--archive", sz_gba.to_str().unwrap()]);
    assert!(ok, "{text}");
    assert!(text.contains("GBATC 0 sections 0 B"), "{text}");
    // tiny profile = 58 species, kt-window 4 over 8 steps = 2 shards
    assert!(text.contains("SZ 116 sections"), "{text}");

    let (ok, text) = run(&[
        "decompress", "--reference", "--input", gba.to_str().unwrap(),
        "--output", rec.to_str().unwrap(),
    ]);
    assert!(ok, "{text}");

    let (ok, text) = run(&[
        "extract", "--reference", "--input", gba.to_str().unwrap(),
        "--output", ext.to_str().unwrap(), "--t0", "2", "--t1", "6",
        "--species", "CO,N2",
    ]);
    assert!(ok, "{text}");

    // bit-equality of the extracted window against the full decode
    let full = gbatc::data::io::read_dataset(&rec).unwrap();
    let part = gbatc::data::io::read_dataset(&ext).unwrap();
    let sel = [
        gbatc::chem::index_of("CO").unwrap(),
        gbatc::chem::index_of("N2").unwrap(),
    ];
    let mut sel = sel.to_vec();
    sel.sort_unstable();
    let npix = full.ny * full.nx;
    assert_eq!((part.nt, part.ns), (4, 2));
    for t in 2..6usize {
        for (k, &s) in sel.iter().enumerate() {
            for p in 0..npix {
                let a = full.mass[(t * full.ns + s) * npix + p];
                let b = part.mass[((t - 2) * 2 + k) * npix + p];
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} s={s} p={p}");
            }
        }
    }

    // typed config errors, before any work is spent
    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--codec", "bogus",
    ]);
    assert!(!ok);
    assert!(text.contains("--codec"), "{text}");
    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--kt-window", "3",
    ]);
    assert!(!ok);
    assert!(text.contains("config error"), "{text}");
    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--queue-depth", "0",
    ]);
    assert!(!ok);
    assert!(text.contains("config error"), "{text}");
    let (ok, text) = run(&[
        "compress", "--reference", "--input", ds.to_str().unwrap(),
        "--output", gba.to_str().unwrap(), "--codec", "auto", "--v1",
    ]);
    assert!(!ok);
    assert!(text.contains("--v1"), "{text}");

    std::fs::remove_dir_all(&dir).ok();
}
