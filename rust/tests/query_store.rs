//! `gbatc::store` correctness: cached and uncached query paths must
//! return bit-identical bytes, warm queries must decode zero new
//! sections and read zero archive bytes, eviction under a tiny byte
//! budget must never corrupt responses, and N concurrent threads issuing
//! randomized overlapping queries must each match a fresh
//! single-threaded `decompress_range`.

use std::sync::Arc;

use gbatc::api::{Query, SpeciesSel};
use gbatc::archive::{Gba2Archive, SliceSource};
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

/// Smooth multi-species field with per-species offsets and mild noise.
fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

/// Compress a 16-timestep field into a 4-shard archive.
fn build_archive(handle: &ExecHandle, nt: usize, kt_window: usize) -> Vec<u8> {
    let comp = GbatcCompressor::new(handle, 0, 0);
    let ds = make_ds(nt, 1);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    comp.compress(&ds, &opts).expect("compress").archive.into_bytes()
}

fn store_cfg(cache_bytes: usize, cache_shards: usize) -> StoreConfig {
    StoreConfig {
        threads: 2,
        cache_bytes,
        cache_shards,
        ..StoreConfig::default()
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: mismatch at {i}: {x} vs {y}");
    }
}

#[test]
fn warm_cache_decodes_zero_sections_and_is_bit_identical() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16, 4);
    let comp = GbatcCompressor::new(&handle, 0, 0);

    let store = ArchiveStore::with_handle(&handle, store_cfg(32 << 20, 8));
    store.mount_bytes("ds", bytes.clone()).unwrap();

    // t 2..10 touches shards 0, 1, 2; two species => 6 planes
    let q = Query {
        time: 2..10,
        species: SpeciesSel::Indices(vec![1, 3]),
    };
    let cold = store.query("ds", &q).unwrap();
    let oracle = comp.extract(&SliceSource(&bytes), 2, 10, &[1, 3], 2).unwrap();
    assert_eq!(cold.species, oracle.species);
    assert_bits_eq(&cold.mass, &oracle.mass, "cold vs decompress_range");

    let s1 = store.stats();
    assert_eq!(s1.decoded_sections, 6);
    assert_eq!(s1.cache.misses, 6);
    assert_eq!(s1.cache.hits, 0);
    let io1 = s1.datasets[0].io;
    assert!(io1.payload_bytes > 0);

    let warm = store.query("ds", &q).unwrap();
    assert_bits_eq(&warm.mass, &cold.mass, "warm vs cold");
    let s2 = store.stats();
    assert_eq!(
        s2.decoded_sections, 6,
        "warm query must decode zero new sections"
    );
    assert_eq!(s2.cache.hits, 6);
    assert_eq!(s2.cache.misses, 6);
    // ...and touch the archive source not at all (the TOC was parsed at
    // mount; planes came from the cache)
    assert_eq!(s2.datasets[0].io, io1, "warm query must read zero archive bytes");

    // a partially-warm query decodes only the genuinely new planes:
    // same window, one cached species + one new one
    let q2 = Query {
        time: 2..10,
        species: SpeciesSel::Indices(vec![0, 1]),
    };
    let mixed = store.query("ds", &q2).unwrap();
    let oracle2 = comp.extract(&SliceSource(&bytes), 2, 10, &[0, 1], 2).unwrap();
    assert_bits_eq(&mixed.mass, &oracle2.mass, "mixed vs decompress_range");
    let s3 = store.stats();
    assert_eq!(s3.decoded_sections, 9, "3 shards x 1 new species");
}

#[test]
fn concurrent_randomized_queries_match_fresh_decode() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let nt = 16;
    let bytes = Arc::new(build_archive(&handle, nt, 4));

    let store = Arc::new(ArchiveStore::with_handle(&handle, store_cfg(32 << 20, 8)));
    store.mount_bytes("ds", bytes.as_ref().clone()).unwrap();

    // pass 0 races cold misses (including duplicate decodes of the same
    // plane); pass 1 runs the same seeds over a warm cache
    for pass in 0..2u64 {
        std::thread::scope(|scope| {
            for w in 0..4u64 {
                let store = Arc::clone(&store);
                let bytes = Arc::clone(&bytes);
                let handle = &handle;
                scope.spawn(move || {
                    let comp = GbatcCompressor::new(handle, 0, 0);
                    let mut rng = Prng::new(1000 + pass * 10 + w);
                    for _ in 0..6 {
                        let t0 = rng.index(nt);
                        let t1 = t0 + 1 + rng.index(nt - t0);
                        let mut sel: Vec<usize> =
                            (0..NS).filter(|_| rng.next_f32() < 0.5).collect();
                        if sel.is_empty() {
                            sel.push(rng.index(NS));
                        }
                        let q = Query {
                            time: t0..t1,
                            species: SpeciesSel::Indices(sel.clone()),
                        };
                        let dec = store.query("ds", &q).unwrap();
                        let oracle = comp
                            .extract(&SliceSource(&bytes), t0, t1, &sel, 1)
                            .unwrap();
                        assert_eq!(dec.species, oracle.species);
                        assert_bits_eq(
                            &dec.mass,
                            &oracle.mass,
                            &format!("pass {pass} worker {w} t {t0}..{t1} sel {sel:?}"),
                        );
                    }
                });
            }
        });
    }
    let s = store.stats();
    assert!(s.cache.hits > 0, "warm pass must hit the cache");
    assert!(
        s.cache.resident_sections <= (4 * NS) as u64,
        "at most one plane per (shard, species): {}",
        s.cache.resident_sections
    );
    assert_eq!(s.queries, 2 * 4 * 6);
}

#[test]
fn tiny_cache_evicts_under_pressure_without_corruption() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16, 4);
    let comp = GbatcCompressor::new(&handle, 0, 0);

    // one plane is 4 * 40 * 40 * 4 = 25600 B; budget holds ~2 of 16
    let store = ArchiveStore::with_handle(&handle, store_cfg(60_000, 1));
    store.mount_bytes("ds", bytes.clone()).unwrap();

    let q = Query {
        time: 0..16,
        species: SpeciesSel::All,
    };
    let oracle = comp.extract(&SliceSource(&bytes), 0, 16, &[], 2).unwrap();
    for round in 0..2 {
        let dec = store.query("ds", &q).unwrap();
        assert_bits_eq(&dec.mass, &oracle.mass, &format!("evicting round {round}"));
    }
    let s = store.stats();
    assert!(s.cache.evicted > 0, "tiny budget must evict");
    assert!(
        s.cache.resident_bytes <= s.cache.capacity_bytes,
        "resident {} over capacity {}",
        s.cache.resident_bytes,
        s.cache.capacity_bytes
    );
}

#[test]
fn typed_errors_unmount_purge_and_gba1_mounts() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16, 4);
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let store = ArchiveStore::with_handle(&handle, store_cfg(32 << 20, 4));

    store.mount_bytes("ds", bytes.clone()).unwrap();
    // unknown dataset lists what is mounted
    let err = store
        .query("nope", &Query { time: 0..4, species: SpeciesSel::All })
        .unwrap_err()
        .to_string();
    assert!(err.contains("available"), "{err}");
    // bad ranges and duplicate/invalid mounts are typed errors
    assert!(store
        .query("ds", &Query { time: 8..4, species: SpeciesSel::All })
        .is_err());
    assert!(store
        .query("ds", &Query { time: 0..99, species: SpeciesSel::All })
        .is_err());
    let err = store.mount_bytes("ds", bytes.clone()).unwrap_err().to_string();
    assert!(err.contains("already mounted"), "{err}");
    assert!(store.mount_bytes("bad name", bytes.clone()).is_err());
    assert!(store.mount_bytes("garbage", b"not an archive".to_vec()).is_err());

    // unmount purges the cache
    store
        .query("ds", &Query { time: 0..4, species: SpeciesSel::All })
        .unwrap();
    assert!(store.stats().cache.resident_sections > 0);
    store.unmount("ds").unwrap();
    assert!(!store.contains("ds"));
    assert_eq!(store.stats().cache.resident_sections, 0);
    assert!(store.unmount("ds").is_err());

    // a legacy GBA1 archive mounts as its one-shard GBA2 view and
    // queries bit-identically to the v2 original
    let single = build_archive(&handle, 4, 4);
    let v1 = Gba2Archive::deserialize(&single)
        .unwrap()
        .to_v1()
        .unwrap()
        .serialize();
    store.mount_bytes("legacy", v1).unwrap();
    let dec = store
        .query(
            "legacy",
            &Query { time: 1..3, species: SpeciesSel::Indices(vec![0, 2]) },
        )
        .unwrap();
    let oracle = comp.extract(&SliceSource(&single), 1, 3, &[0, 2], 1).unwrap();
    assert_bits_eq(&dec.mass, &oracle.mass, "GBA1 mount vs v2 decode");
}
