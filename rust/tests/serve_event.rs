//! Event-driven serving tier tests: keep-alive connection reuse,
//! pipelining with strict response ordering, byte-dribble framing over
//! a real socket, slow-reader backpressure/fairness, and consistent-hash
//! replica routing with warm-cache affinity and mount failover.
//!
//! Every server test here must pass in **both** server modes — CI runs
//! this suite twice, once natively (epoll event loop on Linux) and once
//! with `GBATC_NO_EPOLL=1` (thread-pool fallback) — so assertions stick
//! to protocol behavior and counters both modes guarantee.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gbatc::api::Query;
use gbatc::archive::SliceSource;
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::serve::http;
use gbatc::serve::{QueryClient, QueryRouter, QueryServer, ServerConfig};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

fn build_archive(handle: &ExecHandle, nt: usize) -> Vec<u8> {
    let comp = GbatcCompressor::new(handle, 0, 0);
    let ds = make_ds(nt, 1);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    comp.compress(&ds, &opts).expect("compress").archive.into_bytes()
}

fn start_server(
    handle: &ExecHandle,
    bytes: &[u8],
    cfg: ServerConfig,
) -> (QueryServer, String) {
    let store = Arc::new(ArchiveStore::with_handle(
        handle,
        StoreConfig {
            threads: 1,
            cache_bytes: 32 << 20,
            cache_shards: 8,
            ..StoreConfig::default()
        },
    ));
    store.mount_bytes("hcci", bytes.to_vec()).unwrap();
    let server = QueryServer::bind(store, "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();
    (server, addr)
}

#[test]
fn keepalive_client_opens_exactly_one_connection() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16);
    let (server, addr) = start_server(&handle, &bytes, ServerConfig::default());

    let client = QueryClient::new(addr);
    let comp = GbatcCompressor::new(&handle, 0, 0);
    // M sequential queries (cold then warm repeats) over one socket
    let windows = [(0usize, 8usize), (0, 8), (4, 12), (0, 8), (4, 12)];
    for &(t0, t1) in &windows {
        let dec = client.query("hcci", Some(t0), Some(t1), "1,3").unwrap();
        let oracle = comp
            .extract(&SliceSource(&bytes), t0, t1, &[1, 3], 1)
            .unwrap();
        for (a, b) in dec.mass.iter().zip(&oracle.mass) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    // the /stats body itself must report the reuse
    let stats = client.stats_json().unwrap();
    assert!(stats.contains("\"keepalive_reuse\""), "{stats}");
    assert!(stats.contains("\"active_conns\""), "{stats}");
    assert!(stats.contains("\"replicas\""), "{stats}");

    assert_eq!(client.connections_opened(), 1, "keep-alive must reuse");
    let st = server.shutdown();
    assert_eq!(st.accepted, 1, "{st}");
    assert_eq!(st.served, 6, "5 queries + /stats: {st}");
    assert_eq!(st.keepalive_reuse, 5, "{st}");
    assert_eq!(st.io_errors, 0, "{st}");
    assert_eq!(st.active_conns, 0, "{st}");
}

#[test]
fn pipelined_requests_come_back_in_order() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16);
    let (server, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 4,
            ..ServerConfig::default()
        },
    );
    let comp = GbatcCompressor::new(&handle, 0, 0);

    // 8 pipelined requests in ONE write: alternating species selections
    // (cold/warm mix, so internal completion order is scrambled), with a
    // 404 in the middle and `Connection: close` only on the last
    let sels: [&[usize]; 2] = [&[1, 3], &[0, 2]];
    let mut wire = Vec::new();
    for i in 0..8 {
        if i == 3 {
            wire.extend_from_slice(b"GET /nothing HTTP/1.1\r\n\r\n");
            continue;
        }
        let list = sels[i % 2]
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let close = if i == 7 { "Connection: close\r\n" } else { "" };
        wire.extend_from_slice(
            format!("GET /query?dataset=hcci&t0=0&t1=4&species={list} HTTP/1.1\r\n{close}\r\n")
                .as_bytes(),
        );
    }
    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.write_all(&wire).unwrap();

    // responses must come back strictly in request order
    for i in 0..8 {
        let resp = http::read_response(&mut sock).unwrap();
        if i == 3 {
            assert_eq!(resp.status, 404, "response {i}");
            continue;
        }
        assert_eq!(resp.status, 200, "response {i}");
        let sel = sels[i % 2];
        let oracle = comp.extract(&SliceSource(&bytes), 0, 4, sel, 1).unwrap();
        assert_eq!(resp.body.len(), oracle.mass.len() * 4, "response {i}");
        for (k, (chunk, b)) in resp.body.chunks_exact(4).zip(&oracle.mass).enumerate() {
            let a = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            assert_eq!(a.to_bits(), b.to_bits(), "response {i} value {k}");
        }
    }
    drop(sock);

    let st = server.shutdown();
    assert_eq!(st.accepted, 1, "{st}");
    assert_eq!(st.served, 7, "{st}");
    assert_eq!(st.client_errors, 1, "the 404: {st}");
    assert_eq!(st.io_errors, 0, "{st}");
    // one write of ~8 requests lands in one or two segments on loopback,
    // so most requests parse with no intervening read
    assert!(st.pipelined >= 4, "{st}");
}

#[test]
fn byte_dribble_and_split_crlf_frame_correctly() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);
    let (server, addr) = start_server(&handle, &bytes, ServerConfig::default());

    let mut sock = TcpStream::connect(&addr).unwrap();
    sock.set_nodelay(true).unwrap();
    // dribble the request one byte per write, pausing inside the
    // terminating CRLFCRLF so it spans several TCP segments
    let req = b"GET /datasets HTTP/1.1\r\nConnection: close\r\n\r\n";
    for (i, &b) in req.iter().enumerate() {
        sock.write_all(&[b]).unwrap();
        if i >= req.len() - 4 || i % 7 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let resp = http::read_response(&mut sock).unwrap();
    assert_eq!(resp.status, 200);
    let body = String::from_utf8(resp.body).unwrap();
    assert!(body.contains("\"name\":\"hcci\""), "{body}");
    drop(sock);

    let st = server.shutdown();
    assert_eq!(st.served, 1, "{st}");
    assert_eq!(st.io_errors, 0, "{st}");
}

#[test]
fn slow_reader_does_not_starve_other_clients() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16);
    let (server, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 2,
            // full-axis responses are ~400 KiB each; cap the per-conn
            // write buffer well below that so the slow reader's backlog
            // trips backpressure instead of buffering without bound
            write_buf_bytes: 64 * 1024,
            ..ServerConfig::default()
        },
    );
    let comp = GbatcCompressor::new(&handle, 0, 0);

    // slow reader: pipeline 4 full-volume queries, then read NOTHING yet
    let mut slow = TcpStream::connect(&addr).unwrap();
    let mut wire = Vec::new();
    for i in 0..4 {
        let close = if i == 3 { "Connection: close\r\n" } else { "" };
        wire.extend_from_slice(
            format!("GET /query?dataset=hcci HTTP/1.1\r\n{close}\r\n").as_bytes(),
        );
    }
    slow.write_all(&wire).unwrap();

    // while the slow reader's responses are stuck behind its unread
    // socket, a well-behaved client must be served promptly (the test
    // hangs here if a blocked writer can starve the serving loop)
    let client = QueryClient::new(addr.clone());
    for _ in 0..3 {
        let dec = client.query("hcci", Some(0), Some(4), "1").unwrap();
        let oracle = comp.extract(&SliceSource(&bytes), 0, 4, &[1], 1).unwrap();
        for (a, b) in dec.mass.iter().zip(&oracle.mass) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    // now drain the slow connection: all 4 responses, in order, intact
    let oracle = comp.extract(&SliceSource(&bytes), 0, 16, &[], 1).unwrap();
    for i in 0..4 {
        let resp = http::read_response(&mut slow).unwrap();
        assert_eq!(resp.status, 200, "slow response {i}");
        assert_eq!(resp.body.len(), oracle.mass.len() * 4, "slow response {i}");
        for (chunk, b) in resp.body.chunks_exact(4).zip(&oracle.mass) {
            let a = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    drop(slow);

    let st = server.shutdown();
    assert_eq!(st.served, 7, "4 slow + 3 fast: {st}");
    assert_eq!(st.io_errors, 0, "{st}");
    assert_eq!(st.active_conns, 0, "{st}");
}

#[test]
fn router_warm_affinity_and_mount_failover() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);

    // 3 replicas sharing the test's executor service (the default
    // `QueryRouter::new` would start a reference backend whose spec
    // doesn't match this test archive)
    let store_cfg = StoreConfig {
        threads: 1,
        cache_bytes: 16 << 20,
        cache_shards: 4,
        ..StoreConfig::default()
    };
    let replicas: Vec<_> = (0..3)
        .map(|_| Arc::new(ArchiveStore::with_handle(&handle, store_cfg.clone())))
        .collect();
    let router = QueryRouter::from_replicas(replicas, 64).unwrap();

    // mounts land on their ring-home replica
    let names = ["flame-a", "flame-b", "flame-c", "flame-d", "flame-e"];
    for name in &names {
        let r = router.mount_bytes(name, bytes.clone()).unwrap();
        assert_eq!(r, router.primary_of(name), "{name} should mount at home");
        assert_eq!(r, router.route_of(name));
    }

    // repeat queries for one dataset hit the SAME replica's cache:
    // query twice, then check per-replica counters
    let name = "flame-a";
    let home = router.route_of(name);
    let q = Query::all(8);
    assert!(!router.is_warm(name, &q), "nothing decoded yet");
    router.query(name, &q).unwrap();
    assert!(router.is_warm(name, &q), "first query must warm the cache");
    router.query(name, &q).unwrap();
    let per = router.replica_stats();
    for (i, s) in per.iter().enumerate() {
        if i == home {
            assert_eq!(s.queries, 2, "replica {i}");
            assert!(s.cache.hits > 0, "second query must hit replica {i}'s cache");
        } else {
            assert_eq!(s.queries, 0, "replica {i} must stay cold");
            assert_eq!(s.cache.hits, 0, "replica {i} must stay cold");
        }
    }

    // failover: occupy a fresh name's home replica out-of-band, then the
    // router mount must walk the ring to a sibling and record it
    let name = "failover-ds";
    let home = router.primary_of(name);
    router
        .replica(home)
        .mount_bytes(name, bytes.clone())
        .unwrap();
    let placed = router.mount_bytes(name, bytes.clone()).unwrap();
    assert_ne!(placed, home, "home was occupied; mount must fail over");
    assert_eq!(router.route_of(name), placed, "failover placement sticks");
    let before = router.replica_stats()[placed].queries;
    router.query(name, &q).unwrap();
    let per = router.replica_stats();
    assert_eq!(per[placed].queries, before + 1, "query followed the failover");
    // aggregate stats sum across replicas
    assert_eq!(router.stats().queries, per.iter().map(|s| s.queries).sum::<u64>());
}

#[test]
fn bytes_out_counts_every_response_exactly_once() {
    // the sum of wire bytes clients actually receive must equal the
    // server's bytes_out counter — one bump per response, no double
    // counting, identical in both server modes (CI's GBATC_NO_EPOLL leg)
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);
    let (server, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 2,
            queue: 8,
            ..ServerConfig::default()
        },
    );

    // raw byte-exact fetch: `Connection: close` means read-to-EOF is
    // exactly one serialized response, binary bodies included
    let fetch = |req: &[u8]| -> Vec<u8> {
        let mut s = TcpStream::connect(&addr).expect("connect");
        let _ = s.write_all(req);
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        buf
    };

    let reqs: [&[u8]; 6] = [
        b"GET /datasets HTTP/1.1\r\nConnection: close\r\n\r\n",
        b"GET /query?dataset=hcci&t0=0&t1=4&species=1 HTTP/1.1\r\nConnection: close\r\n\r\n",
        b"GET /query?dataset=hcci&t0=0&t1=4&species=1 HTTP/1.1\r\nConnection: close\r\n\r\n",
        b"GET /nothing HTTP/1.1\r\nConnection: close\r\n\r\n",
        b"GET /query?dataset=nope HTTP/1.1\r\nConnection: close\r\n\r\n",
        b"GET /stats HTTP/1.1\r\nConnection: close\r\n\r\n",
    ];
    let mut wire = 0u64;
    for req in reqs {
        let resp = fetch(req);
        assert!(resp.starts_with(b"HTTP/1.1 "), "no status line");
        wire += resp.len() as u64;
    }

    let st = server.shutdown();
    assert_eq!(st.served + st.client_errors, 6, "{st}");
    assert_eq!(st.server_errors, 0, "{st}");
    assert_eq!(
        st.bytes_out, wire,
        "bytes_out must count each response exactly once: {st}"
    );
}
