//! Observability-layer contracts: histogram quantile accuracy against an
//! exact sorted-sample oracle across adversarial distributions, and the
//! Prometheus text exposition staying inside the 0.0.4 grammar.

use gbatc::obs::{prom, HistSnapshot, Histogram};

/// Exact quantile of a sorted sample set, matching the rank convention
/// `HistSnapshot::quantile` documents: the value at rank `ceil(q·n)`.
fn oracle(sorted: &[u64], q: f64) -> u64 {
    let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Assert every reported quantile is within the documented 1/64 ≈ 1.6%
/// relative error of the oracle (+2 absolute for the tiny-value region).
fn check_quantiles(name: &str, vals: &mut Vec<u64>) {
    let h = Histogram::new();
    for &v in vals.iter() {
        h.record(v);
    }
    vals.sort_unstable();
    let s = h.snapshot();
    assert_eq!(s.count, vals.len() as u64, "{name}: count");
    assert_eq!(s.max, *vals.last().unwrap(), "{name}: max");
    for q in [0.01, 0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99, 1.0] {
        let exact = oracle(vals, q);
        let est = s.quantile(q);
        let err = (est as f64 - exact as f64).abs();
        assert!(
            err <= exact as f64 / 64.0 + 2.0,
            "{name}: q={q} est={est} exact={exact} (err {err})"
        );
    }
}

/// Deterministic splitmix64 stream (no `rand` in the offline image).
fn splitmix(seed: u64) -> impl FnMut() -> u64 {
    let mut x = seed;
    move || {
        x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = x;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

#[test]
fn quantiles_match_oracle_uniform_wide() {
    let mut rng = splitmix(7);
    // ~[1, 2^48): every octave of the bucket table gets traffic
    let mut vals: Vec<u64> = (0..20_000).map(|_| 1 + (rng() >> 16)).collect();
    check_quantiles("uniform_wide", &mut vals);
}

#[test]
fn quantiles_match_oracle_latency_shaped() {
    // a serve-like distribution: tight 100µs body, 1% 50ms tail spikes
    let mut rng = splitmix(11);
    let mut vals: Vec<u64> = (0..10_000)
        .map(|i| {
            if i % 100 == 0 {
                50_000_000 + rng() % 10_000_000
            } else {
                100_000 + rng() % 20_000
            }
        })
        .collect();
    check_quantiles("latency_shaped", &mut vals);
}

#[test]
fn quantiles_match_oracle_bimodal() {
    // warm-hit vs cold-decode: two far-apart modes, nothing between
    let mut rng = splitmix(13);
    let mut vals: Vec<u64> = (0..8_000)
        .map(|i| {
            if i % 5 == 0 {
                8_000_000 + rng() % 1_000_000
            } else {
                40_000 + rng() % 4_000
            }
        })
        .collect();
    check_quantiles("bimodal", &mut vals);
}

#[test]
fn quantiles_match_oracle_constant_spike() {
    // every sample identical: all quantiles must land on (or within a
    // bucket of) the spike, and max clamps the midpoint estimate
    let mut vals: Vec<u64> = vec![123_456; 5_000];
    check_quantiles("constant_spike", &mut vals);
}

#[test]
fn single_sample_and_empty() {
    let h = Histogram::new();
    h.record(777);
    let s = h.snapshot();
    for q in [0.0, 0.5, 1.0] {
        let est = s.quantile(q);
        assert!(
            (est as f64 - 777.0).abs() <= 777.0 / 64.0 + 2.0,
            "single-sample q={q} -> {est}"
        );
    }
    assert_eq!(s.max, 777);

    let empty = Histogram::new().snapshot();
    assert_eq!(empty.quantile(0.99), 0);
    assert_eq!(empty.mean(), 0.0);
}

#[test]
fn merged_snapshot_equals_combined_stream() {
    // quantiles of merge(a, b) must match one histogram fed both streams
    let mut rng = splitmix(17);
    let a = Histogram::new();
    let b = Histogram::new();
    let combined = Histogram::new();
    for i in 0..6_000u64 {
        let v = 1 + (rng() >> 20);
        if i % 2 == 0 {
            a.record(v);
        } else {
            b.record(v);
        }
        combined.record(v);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    let want = combined.snapshot();
    assert_eq!(merged.count, want.count);
    assert_eq!(merged.sum, want.sum);
    assert_eq!(merged.max, want.max);
    assert_eq!(merged.buckets, want.buckets);
    for q in [0.5, 0.9, 0.99] {
        assert_eq!(merged.quantile(q), want.quantile(q));
    }
}

#[test]
fn concurrent_recording_loses_nothing() {
    // 8 threads hammering one histogram: totals must be exact (the
    // record path is fetch_add, not read-modify-write races)
    let h = Histogram::new();
    let per_thread = 10_000u64;
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let h = &h;
            scope.spawn(move || {
                let mut rng = splitmix(100 + t);
                for _ in 0..per_thread {
                    h.record(1 + rng() % 1_000_000);
                }
            });
        }
    });
    let s = h.snapshot();
    assert_eq!(s.count, 8 * per_thread);
    assert_eq!(s.buckets.iter().sum::<u64>(), 8 * per_thread);
}

// ---- Prometheus text exposition ------------------------------------

/// Minimal 0.0.4 grammar check: every line is a comment (`# HELP` /
/// `# TYPE`) or a sample `name[{labels}] value`; names are valid metric
/// identifiers; every sample's name was declared by a `# TYPE` first;
/// histogram `_bucket` series are cumulative in `le` order and end at
/// `+Inf == _count`.
fn assert_valid_prometheus(text: &str) {
    fn valid_name(s: &str) -> bool {
        !s.is_empty()
            && s.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':') == Some(true)
            && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    let mut typed: Vec<String> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let kind = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            assert!(
                kind == "HELP" || kind == "TYPE",
                "unknown comment kind in: {line}"
            );
            assert!(valid_name(name), "bad metric name in: {line}");
            if kind == "TYPE" {
                let family = parts.next().unwrap_or("");
                assert!(
                    ["counter", "gauge", "histogram", "summary", "untyped"].contains(&family),
                    "bad TYPE in: {line}"
                );
                typed.push(name.to_string());
            }
            continue;
        }
        // sample line: name[{labels}] value
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
        assert!(value.parse::<f64>().is_ok(), "unparsable value in: {line}");
        let name = series.split('{').next().unwrap_or("");
        assert!(valid_name(name), "bad series name in: {line}");
        if let Some(rest) = series.strip_prefix(name) {
            if !rest.is_empty() {
                assert!(
                    rest.starts_with('{') && rest.ends_with('}'),
                    "bad label block in: {line}"
                );
                for pair in rest[1..rest.len() - 1].split(',').filter(|p| !p.is_empty()) {
                    let (k, v) = pair.split_once('=').unwrap_or_else(|| panic!("bad label: {line}"));
                    assert!(valid_name(k), "bad label key in: {line}");
                    assert!(
                        v.starts_with('"') && v.ends_with('"') && v.len() >= 2,
                        "unquoted label value in: {line}"
                    );
                }
            }
        }
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .unwrap_or(name);
        assert!(
            typed.iter().any(|t| t == base || t == name),
            "sample before TYPE declaration: {line}"
        );
    }
    // every histogram family: buckets cumulative, +Inf == _count
    for fam in &typed {
        let buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with(&format!("{fam}_bucket{{")))
            .map(|l| l.rsplit(' ').next().and_then(|v| v.parse().ok()).expect("bucket count"))
            .collect();
        if buckets.is_empty() {
            continue;
        }
        assert!(
            buckets.windows(2).all(|w| w[0] <= w[1]),
            "{fam}: buckets not cumulative: {buckets:?}"
        );
        let count_line = format!("{fam}_count ");
        let count: u64 = text
            .lines()
            .find(|l| l.starts_with(&count_line))
            .and_then(|l| l.rsplit(' ').next())
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("{fam}: histogram without _count"));
        assert_eq!(*buckets.last().unwrap(), count, "{fam}: +Inf != _count");
    }
}

#[test]
fn rendered_exposition_is_valid_prometheus() {
    let h = Histogram::new();
    let mut rng = splitmix(23);
    for _ in 0..3_000 {
        h.record(1_000 + rng() % 100_000_000);
    }
    let mut out = String::new();
    prom::render_histogram(&mut out, "gbatc_query_seconds", "end-to-end query latency", &h.snapshot());
    prom::render_histogram(
        &mut out,
        "gbatc_decode_seconds",
        "empty histogram renders too",
        &HistSnapshot::default(),
    );
    prom::render_counter(&mut out, "gbatc_bytes_out_total", "bytes written", 123_456_789);
    prom::render_counter_family(
        &mut out,
        "gbatc_responses_total",
        "responses by status class",
        "class",
        &[("2xx", 40), ("4xx", 2), ("5xx", 0)],
    );
    prom::render_gauge(&mut out, "gbatc_active_connections", "open sockets", 7);
    assert_valid_prometheus(&out);
    // the ladder re-slice is exact: +Inf equals the recorded count
    assert!(out.contains("gbatc_query_seconds_bucket{le=\"+Inf\"} 3000\n"));
    assert!(out.contains("gbatc_decode_seconds_count 0\n"));
}
