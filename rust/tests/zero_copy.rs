//! Zero-copy read-path equivalence: a memory-mapped archive file must
//! serve bit-identical section bytes and query responses to the
//! seek/read [`FileSource`](gbatc::archive::FileSource) and to an
//! in-memory reader, and the mmap path must be observable in the
//! metered IO counters (`IoStats::mmap_bytes`).

use std::io::Cursor;
use std::path::PathBuf;

use gbatc::api::{
    ArchiveReader, Backend, CompressorBuilder, ErrorPolicy, FieldSpec, Query, SpeciesSel,
};
use gbatc::store::{ArchiveStore, StoreConfig};

const NT: usize = 4;
const NS: usize = 58;
const NY: usize = 5;
const NX: usize = 4;

/// Compress a small deterministic field through the session API and
/// return the serialized `GBA2` archive bytes.
fn archive_bytes() -> Vec<u8> {
    let field = FieldSpec {
        nt: NT,
        ns: NS,
        ny: NY,
        nx: NX,
        pressure: 40.0e5,
        ranges: vec![(0.0, 1.0); NS],
    };
    let mut session = CompressorBuilder::new()
        .error_policy(ErrorPolicy::Uniform(1e-2))
        .session(field, Cursor::new(Vec::new()))
        .expect("session");
    for t in 0..NT {
        let frame: Vec<f32> = (0..NS * NY * NX)
            .map(|i| 0.5 + 0.3 * ((i + t * 31) as f32 * 0.11).sin())
            .collect();
        session.push_timestep(&frame).expect("push");
    }
    let (_report, sink) = session.finish_into().expect("finish");
    sink.into_inner()
}

/// Write `bytes` to a unique temp file and return its path.
fn temp_archive(bytes: &[u8], tag: &str) -> PathBuf {
    let path = std::env::temp_dir().join(format!(
        "gbatc_zero_copy_{}_{}.gba2",
        tag,
        std::process::id()
    ));
    std::fs::write(&path, bytes).expect("write temp archive");
    path
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: mismatch at {i}: {x} vs {y}");
    }
}

#[cfg(unix)]
#[test]
fn mmap_source_reads_bit_identical_to_file_source() {
    use gbatc::archive::{FileSource, MmapSource, SectionSource};

    let bytes = archive_bytes();
    let path = temp_archive(&bytes, "raw");
    let map = MmapSource::open(&path).expect("mmap");
    let file = FileSource::open(&path).expect("open");

    assert_eq!(map.source_len(), bytes.len() as u64);
    assert_eq!(map.source_len(), file.source_len());

    let n = bytes.len();
    let windows: [(u64, usize); 5] = [
        (0, 4),             // magic
        (0, n),             // whole file
        (n as u64 - 7, 7),  // tail
        (13, n / 2),        // interior
        (5, 0),             // empty read
    ];
    for (off, len) in windows {
        let a = map.read_at(off, len).expect("mmap read");
        let b = file.read_at(off, len).expect("file read");
        assert_eq!(a, b, "read_at({off}, {len}) differs between mmap and file");
        assert_eq!(a, bytes[off as usize..off as usize + len]);
    }
    // both sources reject out-of-range spans
    assert!(map.read_at(n as u64 - 1, 2).is_err());
    assert!(file.read_at(n as u64 - 1, 2).is_err());

    let _ = std::fs::remove_file(&path);
}

#[test]
fn file_reader_queries_match_in_memory_reader() {
    let bytes = archive_bytes();
    let path = temp_archive(&bytes, "reader");

    let on_disk = ArchiveReader::open_file(&path, &Backend::Reference, 0).expect("open_file");
    let in_mem = ArchiveReader::from_bytes(bytes, &Backend::Reference, 0).expect("from_bytes");

    let queries = [
        Query::all(NT),
        Query::window(1..3),
        Query {
            time: 0..2,
            species: SpeciesSel::Indices(vec![0, 7, 31]),
        },
    ];
    for q in &queries {
        let a = on_disk.query(q).expect("disk query");
        let b = in_mem.query(q).expect("mem query");
        assert_eq!(a.species, b.species);
        assert_bits_eq(&a.mass, &b.mass, "file-backed vs in-memory query");
    }

    // on unix the GBA2 file is memory-mapped, and every byte the queries
    // read is served by the mapping (visible in the classified counters);
    // only the out-of-band 4-byte magic probe at open bypasses it
    let io = on_disk.io_stats();
    if cfg!(unix) {
        assert!(io.mmap_bytes > 0, "mmap counters must move: {io}");
        assert_eq!(io.mmap_bytes, io.bytes() - 4, "all but the magic probe mmap-served: {io}");
        assert_eq!(io.mmap_reads, io.reads() - 1, "all but the magic probe mmap-served: {io}");
    } else {
        assert_eq!(io.mmap_bytes, 0);
    }

    let _ = std::fs::remove_file(&path);
}

#[test]
fn store_mounted_file_queries_match_mounted_bytes() {
    let bytes = archive_bytes();
    let path = temp_archive(&bytes, "store");

    let store = ArchiveStore::new(StoreConfig::default()).expect("store");
    store.mount_file("disk", &path).expect("mount_file");
    store.mount_bytes("mem", bytes).expect("mount_bytes");

    let q = Query {
        time: 0..NT,
        species: SpeciesSel::Indices(vec![2, 3, 40]),
    };
    let cold_disk = store.query("disk", &q).expect("disk query");
    let cold_mem = store.query("mem", &q).expect("mem query");
    assert_bits_eq(&cold_disk.mass, &cold_mem.mass, "mounted file vs mounted bytes");

    // warm repeat: decode totals must not move, and the response stays
    // bit-identical (planes came back as shared cache Arcs)
    let decoded_before = store.stats().decoded_sections;
    let warm_disk = store.query("disk", &q).expect("warm disk query");
    assert_bits_eq(&warm_disk.mass, &cold_disk.mass, "warm vs cold mounted file");
    let stats = store.stats();
    assert_eq!(
        stats.decoded_sections, decoded_before,
        "warm query must decode zero new sections"
    );

    if cfg!(unix) {
        let disk_io = stats
            .datasets
            .iter()
            .find(|d| d.name == "disk")
            .expect("dataset info")
            .io;
        assert!(disk_io.mmap_bytes > 0, "mounted file must be mmap-served: {disk_io}");
    }

    let _ = std::fs::remove_file(&path);
}
