//! Shard-engine tests over the reference runtime: partial decode
//! bit-equality with full decode, byte-counting IO savings, per-shard
//! error-bound verification, GBA1 compatibility, and the shard-bounded
//! peak-memory accounting.

use gbatc::archive::{AnyArchive, CountingSource, SliceSource};
use gbatc::compressor::{CompressOptions, Compressor, GbatcCompressor};
use gbatc::coordinator::engine::{pipeline_workspace_bytes, shard_workspace_bytes};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

/// Smooth multi-species field with per-species offsets and mild noise.
fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

fn compressor(handle: &gbatc::runtime::ExecHandle) -> GbatcCompressor<'_> {
    GbatcCompressor::new(handle, 0, 0)
}

#[test]
fn partial_decode_bit_equals_full_and_reads_fewer_bytes() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    let ds = make_ds(16, 1);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert_eq!(report.n_shards, 4);
    assert!(report.max_block_residual <= report.tau + 1e-9);
    let archive = report.archive;
    let full = comp.decompress(&archive, 2).unwrap();

    let src = SliceSource(&archive.bytes);
    let counting = CountingSource::new(&src);
    let sel = [1usize, 3];
    let (t0, t1) = (4usize, 8usize);
    let out = comp.extract(&counting, t0, t1, &sel, 2).unwrap();
    let npix = NY * NX;
    assert_eq!(out.mass.len(), (t1 - t0) * sel.len() * npix);
    assert_eq!(out.species, vec![1, 3]);

    // bit-identical to the corresponding slice of the full decode
    for t in t0..t1 {
        for (k, &s) in sel.iter().enumerate() {
            for p in 0..npix {
                let a = full[(t * NS + s) * npix + p];
                let b = out.mass[((t - t0) * sel.len() + k) * npix + p];
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "mismatch at t={t} s={s} p={p}: {a} vs {b}"
                );
            }
        }
    }

    // strictly fewer archive bytes than a full read — one of four shards,
    // two of four species sections
    let total = archive.bytes.len() as u64;
    assert!(counting.bytes_read() < total, "read {} of {total}", counting.bytes_read());
    assert!(
        counting.bytes_read() * 2 < total,
        "partial read {} not < half of {total}",
        counting.bytes_read()
    );

    // decode-side workspace regression: a partial decode materializes the
    // output window plus one shard's buffers at a time — never the full
    // [T, S, Y, X] field (the trait default's cost)
    let out_bytes = out.mass.len() * 4;
    let shard_bytes = 4 * NS * npix * 4; // one kt_window=4 shard, normalized
    // slack: latent blob + per-species correction planes of the workers
    let bound = out_bytes + shard_bytes + (96 << 10);
    assert!(
        out.peak_workspace_bytes <= bound,
        "decode peak {} exceeds window+shard bound {bound}",
        out.peak_workspace_bytes
    );
    assert!(
        out.peak_workspace_bytes < ds.mass.len() * 4,
        "decode peak {} not below one full-field copy {}",
        out.peak_workspace_bytes,
        ds.mass.len() * 4
    );
}

#[test]
fn range_spanning_shards_and_all_species_matches_full() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    let ds = make_ds(16, 2);
    let opts = CompressOptions {
        nrmse_target: 3e-3,
        kt_window: 8,
        threads: 2,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert_eq!(report.n_shards, 2);
    let full = comp.decompress(&report.archive, 2).unwrap();
    // [6, 10) straddles the shard boundary at t=8; empty species = all
    let src = SliceSource(&report.archive.bytes);
    let out = comp.extract(&src, 6, 10, &[], 2).unwrap();
    let npix = NY * NX;
    assert_eq!(out.species, vec![0, 1, 2, 3]);
    for t in 6..10 {
        for s in 0..NS {
            for p in 0..npix {
                let a = full[(t * NS + s) * npix + p];
                let b = out.mass[((t - 6) * NS + s) * npix + p];
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
    // out-of-range queries are clean errors
    assert!(comp.extract(&src, 8, 8, &[], 2).is_err());
    assert!(comp.extract(&src, 0, 17, &[], 2).is_err());
    assert!(comp.extract(&src, 0, 4, &[9], 2).is_err());
}

#[test]
fn trait_decompress_range_agrees_with_default_impl() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    let ds = make_ds(8, 3);
    let bytes = comp.compress_bytes(&ds, 2e-3).unwrap();
    // the TOC-walking override...
    let fast = comp.decompress_range(&bytes, 4, 8, &[0, 2]).unwrap();
    // ...must agree bit-for-bit with slicing a full decode (the trait's
    // default strategy)
    let full = comp.decompress_mass(&bytes).unwrap();
    let npix = NY * NX;
    let mut manual = Vec::new();
    for t in 4..8 {
        for &s in &[0usize, 2] {
            manual.extend_from_slice(&full[(t * NS + s) * npix..(t * NS + s + 1) * npix]);
        }
    }
    assert_eq!(fast.len(), manual.len());
    for (a, b) in fast.iter().zip(&manual) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn per_species_guarantee_holds_on_every_shard() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    let ds = make_ds(16, 4);
    let target = 1e-3;
    let opts = CompressOptions {
        nrmse_target: target,
        kt_window: 4,
        threads: 2,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    let full = comp.decompress(&report.archive, 2).unwrap();
    let ranges = ds.species_ranges();
    let npix = NY * NX;
    // NRMSE restricted to every shard window, normalized by the global
    // species range (the units the guarantee certifies)
    for shard in 0..4 {
        let (w0, w1) = (shard * 4, shard * 4 + 4);
        for s in 0..NS {
            let range = (ranges[s].1 - ranges[s].0).max(1e-30) as f64;
            let mut se = 0.0f64;
            let mut n = 0usize;
            for t in w0..w1 {
                let off = (t * NS + s) * npix;
                for p in 0..npix {
                    let e = (ds.mass[off + p] - full[off + p]) as f64 / range;
                    se += e * e;
                    n += 1;
                }
            }
            let nrmse = (se / n as f64).sqrt();
            assert!(
                nrmse <= target * 1.05,
                "shard {shard} species {s}: NRMSE {nrmse} > {target}"
            );
        }
    }
}

#[test]
fn gba1_archives_decode_through_the_new_api() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    let ds = make_ds(8, 5);
    // single shard so the archive is expressible as legacy GBA1
    let opts = CompressOptions {
        nrmse_target: 2e-3,
        kt_window: 8,
        threads: 2,
        ..Default::default()
    };
    let report = comp.compress(&ds, &opts).unwrap();
    assert_eq!(report.n_shards, 1);
    let v2_mass = comp.decompress(&report.archive, 2).unwrap();

    // export as GBA1 (seed format), then read it back through AnyArchive
    let v1 = report.archive.to_v1().unwrap();
    let v1_bytes = v1.serialize();
    let any = AnyArchive::deserialize(&v1_bytes).unwrap();
    assert_eq!(any.version(), 1);
    assert_eq!(any.dims(), (8, NS, NY, NX));
    let as_v2 = any.into_v2().unwrap();
    let v1_mass = comp.decompress(&as_v2, 2).unwrap();
    assert_eq!(v1_mass.len(), v2_mass.len());
    for (a, b) in v1_mass.iter().zip(&v2_mass) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // the trait entry point accepts legacy bytes too
    let trait_mass = comp.decompress_mass(&v1_bytes).unwrap();
    assert_eq!(trait_mass, v1_mass);
}

#[test]
fn peak_memory_bounded_by_shard_window() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let comp = compressor(&handle);
    // field is 8x the 4-step shard window
    let ds = make_ds(32, 6);
    let sharded = CompressOptions {
        nrmse_target: 2e-3,
        kt_window: 4,
        shard_workers: 1,
        threads: 2,
        ..Default::default()
    };
    let r4 = comp.compress(&ds, &sharded).unwrap();
    assert_eq!(r4.n_shards, 8);
    let monolithic = CompressOptions {
        kt_window: 32,
        ..sharded.clone()
    };
    let r32 = comp.compress(&ds, &monolithic).unwrap();
    assert_eq!(r32.n_shards, 1);

    // sharded peak is bounded by one shard's analytic working set...
    let npix = NY * NX;
    let nb_shard = (4 / 4) * (NY / 5) * (NX / 4);
    let shard_values = 4 * NS * npix;
    let est = shard_workspace_bytes(shard_values, nb_shard, 6, 80, 2)
        + pipeline_workspace_bytes(4, 8, NS * 80, shard_values);
    assert!(
        r4.peak_workspace_bytes <= est,
        "peak {} exceeds shard estimate {est}",
        r4.peak_workspace_bytes
    );
    // ...and is several times below the monolithic run on the same field
    assert!(
        r4.peak_workspace_bytes * 4 <= r32.peak_workspace_bytes,
        "sharded peak {} not <= 1/4 of monolithic {}",
        r4.peak_workspace_bytes,
        r32.peak_workspace_bytes
    );
    // both runs produce the same reconstruction quality bound
    assert!(r4.max_block_residual <= r4.tau + 1e-9);
    assert!(r32.max_block_residual <= r32.tau + 1e-9);
}
