//! `gbatc-verify` against its seeded-violation fixtures and the real
//! tree: each fixture must yield exactly one finding of the expected
//! lint, and the repository itself must verify clean.

use std::path::{Path, PathBuf};

use gbatc::analysis::{self, Lint};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/verify_fixtures")
        .join(name)
}

fn run(name: &str) -> Vec<analysis::Finding> {
    analysis::verify_root(&fixture(name))
        .unwrap_or_else(|e| panic!("fixture {name} failed to verify: {e}"))
        .findings
}

fn expect_one(name: &str, lint: Lint, file: &str, line: usize) {
    let findings = run(name);
    assert_eq!(findings.len(), 1, "{name}: want exactly one finding, got {findings:?}");
    let f = &findings[0];
    assert_eq!(f.lint, lint, "{name}: {f}");
    assert_eq!(f.file, file, "{name}: {f}");
    assert_eq!(f.line, line, "{name}: {f}");
}

#[test]
fn missing_safety_comment_is_one_unsafe_audit_finding() {
    expect_one("missing_safety", Lint::UnsafeAudit, "util/a.rs", 4);
}

#[test]
fn mul_add_in_gae_is_one_determinism_finding() {
    expect_one("mul_add_in_gae", Lint::Determinism, "gae/a.rs", 4);
}

#[test]
fn unwrap_in_serve_is_one_panic_freedom_finding_test_side_exempt() {
    expect_one("unwrap_in_serve", Lint::PanicFreedom, "serve/a.rs", 4);
}

#[test]
fn stale_inventory_entry_is_one_manifest_finding() {
    expect_one("stale_inventory", Lint::Manifest, "serve/ghost.rs", 0);
}

#[test]
fn hashmap_in_archive_is_one_determinism_finding() {
    expect_one("hashmap_in_archive", Lint::Determinism, "archive/a.rs", 3);
}

#[test]
fn blocking_call_in_reactor_is_one_blocking_finding() {
    expect_one("blocking_in_reactor", Lint::Blocking, "serve/reactor.rs", 4);
}

#[test]
fn inventory_count_drift_is_one_manifest_finding() {
    let findings = run("inventory_count_drift");
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].lint, Lint::Manifest);
    assert!(
        findings[0].message.contains("expects 1") && findings[0].message.contains("has 2"),
        "{}",
        findings[0]
    );
}

#[test]
fn justified_waiver_at_exact_line_silences_the_finding() {
    let findings = run("waived_unwrap");
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn waiver_matching_nothing_is_one_manifest_finding() {
    let findings = run("stale_waiver");
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].lint, Lint::Manifest);
    assert!(findings[0].message.contains("waiver"), "{}", findings[0]);
}

/// The acceptance gate: the repository's own tree verifies clean
/// against the committed manifest, and the unsafe inventory is
/// non-trivial (the scan really saw the FFI/SIMD surface).
#[test]
fn real_tree_verifies_clean_against_committed_manifest() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent")
        .to_path_buf();
    assert!(
        root.join("verify.toml").is_file(),
        "repo root manifest missing at {}",
        root.display()
    );
    let report = analysis::verify_root(&root).expect("verify_root on the real tree");
    assert!(
        report.findings.is_empty(),
        "the tree must verify clean:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(report.files_scanned > 50, "scanned {} files", report.files_scanned);
    assert!(report.unsafe_sites > 30, "saw {} unsafe sites", report.unsafe_sites);
}

/// `find_root` walks upward from a nested directory.
#[test]
fn find_root_walks_upward() {
    let nested = fixture("missing_safety").join("src/util");
    let found = analysis::find_root(&nested).expect("finds fixture root");
    assert_eq!(found, fixture("missing_safety"));
}
