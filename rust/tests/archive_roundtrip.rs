//! Property tests: `serialize → deserialize` is identity for randomized
//! `GBA1` and `GBA2` archives (including mixed-codec v3 containers),
//! corrupted/truncated containers are rejected with errors (never
//! panics), and corrupt codec tags are rejected at TOC validation.

use gbatc::archive::{
    AnyArchive, Archive, CodecTag, Gba2Archive, Gba2Header, ShardPayload, SpeciesSection,
};
use gbatc::gae::SpeciesBasis;
use gbatc::linalg::Mat;
use gbatc::util::prop::{check, Arbitrary};
use gbatc::util::Prng;

fn random_basis(rng: &mut Prng, d: usize) -> SpeciesBasis {
    let mut m = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            m[(i, j)] = rng.normal();
        }
    }
    let rank = rng.index(d + 1);
    SpeciesBasis::from_mat(&m, rank)
}

fn random_blob(rng: &mut Prng, max: usize) -> Vec<u8> {
    let n = rng.index(max.max(1));
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

#[derive(Clone, Debug)]
struct V1Case(Archive);

impl Arbitrary for V1Case {
    fn generate(rng: &mut Prng) -> Self {
        let kt = 1 + rng.index(4);
        let tb = 1 + rng.index(3);
        let ns = 1 + rng.index(5);
        let d = 2 + rng.index(6);
        let species = (0..ns)
            .map(|_| SpeciesSection {
                basis: random_basis(rng, d),
                coeffs: random_blob(rng, 64),
            })
            .collect();
        V1Case(Archive {
            tcn_used: rng.next_f64() < 0.5,
            dims: (kt * tb, ns, 5 + rng.index(10), 4 + rng.index(8)),
            block: (kt, 1 + rng.index(5), 1 + rng.index(4)),
            latent_dim: 1 + rng.index(64),
            pressure: rng.uniform(1e5, 1e7),
            ranges: (0..ns)
                .map(|_| {
                    let lo = rng.normal() as f32;
                    (lo, lo + rng.next_f32().abs() + 0.1)
                })
                .collect(),
            latent_blob: random_blob(rng, 256),
            species,
            model_param_bytes: rng.next_u64() % (1 << 32),
            nrmse_target: rng.uniform(1e-5, 1e-1),
        })
    }
}

#[test]
fn prop_gba1_serialize_deserialize_identity() {
    check::<V1Case, _>(11, 60, |case| {
        let a = &case.0;
        let bytes = a.serialize();
        let Ok(b) = Archive::deserialize(&bytes) else {
            return false;
        };
        // identity is byte-level: re-serializing must reproduce the input
        bytes == b.serialize()
            && a.dims == b.dims
            && a.block == b.block
            && a.latent_dim == b.latent_dim
            && a.ranges == b.ranges
            && a.latent_blob == b.latent_blob
            && a.species.len() == b.species.len()
            && a.species
                .iter()
                .zip(&b.species)
                .all(|(x, y)| x.coeffs == y.coeffs && x.basis.data == y.basis.data)
            && a.model_param_bytes == b.model_param_bytes
    });
}

#[derive(Clone, Debug)]
struct V2Case {
    header: Gba2Header,
    shards: Vec<ShardPayload>,
}

impl Arbitrary for V2Case {
    fn generate(rng: &mut Prng) -> Self {
        let kt = 1 + rng.index(4);
        let windows = 1 + rng.index(3); // kt blocks per window
        let kt_window = kt * windows;
        let n_shards = 1 + rng.index(4);
        // full windows, except the last may be short
        let mut shards_nt: Vec<usize> = vec![kt_window; n_shards];
        let last = kt * (1 + rng.index(windows));
        shards_nt[n_shards - 1] = last;
        let nt: usize = shards_nt.iter().sum();
        let ns = 1 + rng.index(5);
        let d = 2 + rng.index(6);
        let header = Gba2Header {
            tcn_used: rng.next_f64() < 0.5,
            dims: (nt, ns, 5 + rng.index(10), 4 + rng.index(8)),
            block: (kt, 1 + rng.index(5), 1 + rng.index(4)),
            latent_dim: 1 + rng.index(64),
            kt_window,
            pressure: rng.uniform(1e5, 1e7),
            nrmse_target: rng.uniform(1e-5, 1e-1),
            model_param_bytes: rng.next_u64() % (1 << 32),
            ranges: (0..ns)
                .map(|_| {
                    let lo = rng.normal() as f32;
                    (lo, lo + rng.next_f32().abs() + 0.1)
                })
                .collect(),
        };
        // roughly half the cases are mixed-codec (v3) containers
        let mixed = rng.next_f64() < 0.5;
        let mut t0 = 0;
        let shards = shards_nt
            .iter()
            .map(|&w| {
                let codecs: Vec<CodecTag> = (0..ns)
                    .map(|_| {
                        if mixed {
                            CodecTag::ALL[rng.index(3)]
                        } else {
                            CodecTag::Gbatc
                        }
                    })
                    .collect();
                let species = codecs
                    .iter()
                    .map(|&c| {
                        if c == CodecTag::Gbatc {
                            SpeciesSection {
                                basis: random_basis(rng, d),
                                coeffs: random_blob(rng, 64),
                            }
                            .to_bytes()
                        } else {
                            // self-contained stages are opaque at the
                            // container layer
                            random_blob(rng, 96)
                        }
                    })
                    .collect();
                let sh = ShardPayload {
                    t0,
                    nt: w,
                    latent_blob: random_blob(rng, 256),
                    species,
                    codecs,
                };
                t0 += w;
                sh
            })
            .collect();
        V2Case { header, shards }
    }
}

#[test]
fn prop_gba2_build_deserialize_identity() {
    check::<V2Case, _>(13, 60, |case| {
        let Ok(a) = Gba2Archive::build(case.header.clone(), case.shards.clone()) else {
            return false;
        };
        let Ok(b) = Gba2Archive::deserialize(&a.bytes) else {
            return false;
        };
        if a.bytes != b.serialize() || a.toc.len() != case.shards.len() {
            return false;
        }
        // every section round-trips byte-identically, tags included
        case.shards.iter().enumerate().all(|(i, sh)| {
            b.latent_bytes(i).map(|l| l == &sh.latent_blob[..]).unwrap_or(false)
                && b.toc[i].codecs == sh.codecs
                && sh.species.iter().enumerate().all(|(s, sec)| {
                    b.species_bytes(i, s).map(|x| x == &sec[..]).unwrap_or(false)
                })
        })
    });
}

#[test]
fn prop_mixed_codec_roundtrip_through_any_archive_and_tag_corruption() {
    check::<V2Case, _>(29, 40, |case| {
        let Ok(a) = Gba2Archive::build(case.header.clone(), case.shards.clone()) else {
            return false;
        };
        // bit-identical round trip through the version-dispatching reader
        let Ok(any) = AnyArchive::deserialize(&a.bytes) else {
            return false;
        };
        let Ok(back) = any.into_v2() else {
            return false;
        };
        if back.serialize() != a.bytes {
            return false;
        }
        let mixed = case
            .shards
            .iter()
            .any(|sh| sh.codecs.iter().any(|&c| c != CodecTag::Gbatc));
        if a.version() != if mixed { 3 } else { 2 } {
            return false;
        }
        if !mixed {
            return true;
        }
        // corrupting any codec tag to an invalid value must be rejected
        // at TOC validation (deserialize), not at section decode
        let ns = case.header.dims.1;
        for (i, sh) in case.shards.iter().enumerate() {
            for s in 0..sh.codecs.len() {
                let pos = gbatc::archive::toc::codec_tag_offset(ns, i, s);
                // the helper must point at the byte the writer stored
                if a.bytes[pos] != sh.codecs[s] as u8 {
                    return false;
                }
                let mut bad = a.bytes.clone();
                bad[pos] = 0xEE;
                if Gba2Archive::deserialize(&bad).is_ok() {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn prop_gba2_truncation_always_rejected() {
    check::<V2Case, _>(17, 25, |case| {
        let Ok(a) = Gba2Archive::build(case.header.clone(), case.shards.clone()) else {
            return false;
        };
        // any strict prefix must fail to parse (header, TOC, or payload
        // extent checks), and must never panic
        let n = a.bytes.len();
        let step = (n / 23).max(1);
        (0..n)
            .step_by(step)
            .chain([n - 1])
            .all(|cut| Gba2Archive::deserialize(&a.bytes[..cut]).is_err())
    });
}

#[test]
fn prop_gba2_bit_flips_never_panic() {
    check::<V2Case, _>(19, 15, |case| {
        let Ok(a) = Gba2Archive::build(case.header.clone(), case.shards.clone()) else {
            return false;
        };
        let mut rng = Prng::new(a.bytes.len() as u64);
        for _ in 0..200 {
            let i = rng.index(a.bytes.len());
            let mut corrupt = a.bytes.clone();
            corrupt[i] ^= 1 << rng.index(8);
            let _ = Gba2Archive::deserialize(&corrupt); // Err is fine, panic is not
        }
        true
    });
}

#[test]
fn corrupted_header_fields_rejected() {
    let mut rng = Prng::new(5);
    let case = V2Case::generate(&mut rng);
    let a = Gba2Archive::build(case.header, case.shards).unwrap();
    // magic
    let mut bad = a.bytes.clone();
    bad[..4].copy_from_slice(b"NOPE");
    assert!(Gba2Archive::deserialize(&bad).is_err());
    // version
    let mut bad = a.bytes.clone();
    bad[4] = 0xFF;
    assert!(Gba2Archive::deserialize(&bad).is_err());
    // species count zeroed
    let mut bad = a.bytes.clone();
    bad[12..16].copy_from_slice(&0u32.to_le_bytes());
    assert!(Gba2Archive::deserialize(&bad).is_err());
    // shard count inflated — TOC now larger than the file
    let mut bad = a.bytes.clone();
    bad[44..48].copy_from_slice(&u32::MAX.to_le_bytes());
    assert!(Gba2Archive::deserialize(&bad).is_err());
}
