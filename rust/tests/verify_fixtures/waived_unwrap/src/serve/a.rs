//! Fixture: the one unwrap here carries a justified waiver at its
//! exact line, so the run is clean.

pub fn boot(v: &[u32]) -> u32 {
    *v.first().unwrap()
}
