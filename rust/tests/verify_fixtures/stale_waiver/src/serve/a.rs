//! Fixture: a clean tree plus a waiver that matches nothing — the
//! stale waiver itself must be the one finding.

pub fn ok() -> u32 {
    7
}
