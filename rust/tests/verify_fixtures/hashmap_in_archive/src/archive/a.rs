//! Fixture: hash-ordered iteration where archive bytes are produced.

pub fn tag_bytes(tags: &std::collections::HashMap<u32, u8>) -> Vec<u8> {
    tags.values().copied().collect()
}
