//! Fixture: one request-path unwrap; the test-module unwrap is exempt.

pub fn first(v: &[u32]) -> u32 {
    *v.first().unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_side_unwrap_is_fine() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
