//! Fixture: clean file; the manifest lists an inventory entry for a
//! file that does not exist.

pub fn ok() -> u32 {
    7
}
