//! Fixture: one unsafe block with no safety rationale comment.

pub fn read_first(p: *const u8) -> u8 {
    unsafe { *p }
}
