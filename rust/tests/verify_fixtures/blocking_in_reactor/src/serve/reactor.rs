//! Fixture: a filesystem call on the event-loop thread.

pub fn probe(path: &str) -> bool {
    std::fs::metadata(path).is_ok()
}
