//! Fixture: a fused multiply-add in an archive-byte-producing module.

pub fn accumulate(a: f64, b: f64, c: f64) -> f64 {
    a.mul_add(b, c)
}
