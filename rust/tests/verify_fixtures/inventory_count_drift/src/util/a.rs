//! Fixture: two documented unsafe sites, but the manifest admits one.

pub fn read_first(p: *const u8) -> u8 {
    // SAFETY: caller contract — p is valid for reads.
    unsafe { *p }
}

pub fn read_second(p: *const u8) -> u8 {
    // SAFETY: caller contract — p + 1 is valid for reads.
    unsafe { *p.add(1) }
}
