//! End-to-end loopback tests of `gbatc::serve`: a real server on an
//! ephemeral port, concurrent clients whose responses must be
//! bit-identical to a local decode, protocol-abuse survival (malformed,
//! oversized, unknown — workers must answer the next request fine), and
//! graceful shutdown with accurate counters.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gbatc::archive::SliceSource;
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::serve::{QueryClient, QueryServer, ServerConfig};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

fn build_archive(handle: &ExecHandle, nt: usize) -> Vec<u8> {
    let comp = GbatcCompressor::new(handle, 0, 0);
    let ds = make_ds(nt, 1);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    comp.compress(&ds, &opts).expect("compress").archive.into_bytes()
}

fn start_server(
    handle: &ExecHandle,
    bytes: &[u8],
    cfg: ServerConfig,
) -> (QueryServer, Arc<ArchiveStore>, String) {
    let store = Arc::new(ArchiveStore::with_handle(
        handle,
        StoreConfig {
            threads: 1,
            cache_bytes: 32 << 20,
            cache_shards: 8,
            ..StoreConfig::default()
        },
    ));
    store.mount_bytes("hcci", bytes.to_vec()).unwrap();
    let server = QueryServer::bind(Arc::clone(&store), "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();
    (server, store, addr)
}

/// One raw request, whole response as text.  Well-formed requests here
/// carry `Connection: close` — the server now speaks keep-alive, and
/// `read_to_end` would otherwise wait out the idle timeout.  (Malformed
/// and oversized requests close unconditionally.)
fn raw(addr: &str, req: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    // the server may answer (and close) before consuming everything we
    // send, so a late write failure is acceptable here
    let _ = s.write_all(req);
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

#[test]
fn loopback_concurrent_clients_bit_identical() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16);
    let (server, _store, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 4,
            queue: 16,
            ..ServerConfig::default()
        },
    );

    // >= 4 concurrent clients with overlapping windows/species; every
    // wire response must match a fresh local decompress_range bit for bit
    std::thread::scope(|scope| {
        for w in 0..6usize {
            let addr = addr.clone();
            let bytes = &bytes;
            let handle = &handle;
            scope.spawn(move || {
                let client = QueryClient::new(addr);
                let comp = GbatcCompressor::new(handle, 0, 0);
                let (t0, t1) = match w % 3 {
                    0 => (0usize, 8usize),
                    1 => (4, 12),
                    _ => (2, 16),
                };
                let sel: Vec<usize> = if w % 2 == 0 { vec![1, 3] } else { vec![0, 2] };
                let list = sel
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let dec = client.query("hcci", Some(t0), Some(t1), &list).unwrap();
                let oracle = comp.extract(&SliceSource(bytes), t0, t1, &sel, 1).unwrap();
                assert_eq!(dec.species, sel);
                assert_eq!((dec.t0, dec.nt, dec.ny, dec.nx), (t0, t1 - t0, NY, NX));
                assert_eq!(dec.mass.len(), oracle.mass.len());
                for (i, (a, b)) in dec.mass.iter().zip(&oracle.mass).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "client {w} t {t0}..{t1} sel {sel:?} idx {i}"
                    );
                }
            });
        }
    });

    let client = QueryClient::new(addr);
    let cat = client.datasets_json().unwrap();
    assert!(cat.contains("\"name\":\"hcci\""), "{cat}");
    assert!(cat.contains("\"nt\":16"), "{cat}");
    let stats = client.stats_json().unwrap();
    assert!(stats.contains("\"hits\""), "{stats}");
    assert!(stats.contains("\"server\""), "{stats}");
    assert!(stats.contains("\"payload_bytes\""), "{stats}");

    let st = server.shutdown();
    assert_eq!(st.served, 8, "6 queries + /datasets + /stats: {st}");
    assert_eq!(st.io_errors, 0, "{st}");
    // keep-alive: 6 one-query clients + 1 client reusing a single
    // connection for /datasets and /stats
    assert_eq!(st.accepted, 7, "{st}");
    assert_eq!(st.keepalive_reuse, 1, "{st}");
    assert_eq!(st.active_conns, 0, "{st}");
}

#[test]
fn server_survives_protocol_abuse_then_serves() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);
    let (server, _store, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 2,
            queue: 8,
            ..ServerConfig::default()
        },
    );

    // malformed request line
    let r = raw(&addr, b"NONSENSE\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");
    // oversized head (default cap 8 KiB)
    let big = format!(
        "GET /query?dataset={} HTTP/1.1\r\n\r\n",
        "x".repeat(20_000)
    );
    let r = raw(&addr, big.as_bytes());
    assert!(r.starts_with("HTTP/1.1 431"), "{r}");
    // wrong method / unknown endpoint
    let r = raw(&addr, b"POST /query HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 405"), "{r}");
    let r = raw(&addr, b"GET /nothing HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 404"), "{r}");
    // missing dataset parameter
    let r = raw(&addr, b"GET /query HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");

    // typed client-side errors carry the status and the server's message
    let client = QueryClient::new(addr.clone());
    let err = client.query("nope", None, None, "").unwrap_err().to_string();
    assert!(err.contains("404"), "{err}");
    let err = client.query("hcci", Some(6), Some(2), "").unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    let err = client
        .query("hcci", None, None, "not_a_species")
        .unwrap_err()
        .to_string();
    assert!(err.contains("400"), "{err}");
    let err = client
        .query("hcci", Some(0), Some(999), "")
        .unwrap_err()
        .to_string();
    assert!(err.contains("400"), "{err}");

    // after all the abuse, the same workers serve a correct response —
    // defaults resolve to the full axis and all species
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let dec = client.query("hcci", None, None, "").unwrap();
    assert_eq!((dec.t0, dec.nt), (0, 8));
    assert_eq!(dec.species, vec![0, 1, 2, 3]);
    let oracle = comp.extract(&SliceSource(&bytes), 0, 8, &[], 1).unwrap();
    for (a, b) in dec.mass.iter().zip(&oracle.mass) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    let st = server.shutdown();
    assert_eq!(st.served, 1, "{st}");
    assert!(st.client_errors >= 9, "{st}");
    assert_eq!(st.server_errors, 0, "{st}");
    // 5 raw abuse connections + the typed client's single keep-alive
    // connection carrying all 5 of its requests (4 errors + 1 hit)
    assert_eq!(st.accepted, 6, "{st}");
    assert_eq!(st.keepalive_reuse, 4, "{st}");
    assert_eq!(client.connections_opened(), 1);
}
