//! End-to-end loopback tests of `gbatc::serve`: a real server on an
//! ephemeral port, concurrent clients whose responses must be
//! bit-identical to a local decode, protocol-abuse survival (malformed,
//! oversized, unknown — workers must answer the next request fine), and
//! graceful shutdown with accurate counters.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use gbatc::archive::SliceSource;
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecHandle, ExecService, RuntimeSpec};
use gbatc::serve::{QueryClient, QueryServer, ServerConfig};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Prng;

const NS: usize = 4;
const NY: usize = 40;
const NX: usize = 40;

fn small_spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

fn make_ds(nt: usize, seed: u64) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    let mut rng = Prng::new(seed);
    for t in 0..nt {
        for s in 0..NS {
            for y in 0..NY {
                for x in 0..NX {
                    let v = (t as f32 * 0.3 + s as f32 * 1.7).sin() * 0.2
                        + (y as f32 * 0.17 + x as f32 * 0.11 + s as f32).cos() * 0.3
                        + s as f32 * 0.5
                        + rng.next_f32() * 0.02;
                    let i = ds.idx(t, s, y, x);
                    ds.mass[i] = v;
                }
            }
        }
    }
    ds
}

fn build_archive(handle: &ExecHandle, nt: usize) -> Vec<u8> {
    let comp = GbatcCompressor::new(handle, 0, 0);
    let ds = make_ds(nt, 1);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        shard_workers: 2,
        threads: 2,
        ..Default::default()
    };
    comp.compress(&ds, &opts).expect("compress").archive.into_bytes()
}

fn start_server(
    handle: &ExecHandle,
    bytes: &[u8],
    cfg: ServerConfig,
) -> (QueryServer, Arc<ArchiveStore>, String) {
    let store = Arc::new(ArchiveStore::with_handle(
        handle,
        StoreConfig {
            threads: 1,
            cache_bytes: 32 << 20,
            cache_shards: 8,
            ..StoreConfig::default()
        },
    ));
    store.mount_bytes("hcci", bytes.to_vec()).unwrap();
    let server = QueryServer::bind(Arc::clone(&store), "127.0.0.1:0", cfg).unwrap();
    let addr = server.addr().to_string();
    (server, store, addr)
}

/// One raw request, whole response as text.  Well-formed requests here
/// carry `Connection: close` — the server now speaks keep-alive, and
/// `read_to_end` would otherwise wait out the idle timeout.  (Malformed
/// and oversized requests close unconditionally.)
fn raw(addr: &str, req: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    // the server may answer (and close) before consuming everything we
    // send, so a late write failure is acceptable here
    let _ = s.write_all(req);
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    String::from_utf8_lossy(&buf).into_owned()
}

#[test]
fn loopback_concurrent_clients_bit_identical() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 16);
    let (server, _store, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 4,
            queue: 16,
            ..ServerConfig::default()
        },
    );

    // >= 4 concurrent clients with overlapping windows/species; every
    // wire response must match a fresh local decompress_range bit for bit
    std::thread::scope(|scope| {
        for w in 0..6usize {
            let addr = addr.clone();
            let bytes = &bytes;
            let handle = &handle;
            scope.spawn(move || {
                let client = QueryClient::new(addr);
                let comp = GbatcCompressor::new(handle, 0, 0);
                let (t0, t1) = match w % 3 {
                    0 => (0usize, 8usize),
                    1 => (4, 12),
                    _ => (2, 16),
                };
                let sel: Vec<usize> = if w % 2 == 0 { vec![1, 3] } else { vec![0, 2] };
                let list = sel
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>()
                    .join(",");
                let dec = client.query("hcci", Some(t0), Some(t1), &list).unwrap();
                let oracle = comp.extract(&SliceSource(bytes), t0, t1, &sel, 1).unwrap();
                assert_eq!(dec.species, sel);
                assert_eq!((dec.t0, dec.nt, dec.ny, dec.nx), (t0, t1 - t0, NY, NX));
                assert_eq!(dec.mass.len(), oracle.mass.len());
                for (i, (a, b)) in dec.mass.iter().zip(&oracle.mass).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "client {w} t {t0}..{t1} sel {sel:?} idx {i}"
                    );
                }
            });
        }
    });

    let client = QueryClient::new(addr);
    let cat = client.datasets_json().unwrap();
    assert!(cat.contains("\"name\":\"hcci\""), "{cat}");
    assert!(cat.contains("\"nt\":16"), "{cat}");
    let stats = client.stats_json().unwrap();
    assert!(stats.contains("\"hits\""), "{stats}");
    assert!(stats.contains("\"server\""), "{stats}");
    assert!(stats.contains("\"payload_bytes\""), "{stats}");

    let st = server.shutdown();
    assert_eq!(st.served, 8, "6 queries + /datasets + /stats: {st}");
    assert_eq!(st.io_errors, 0, "{st}");
    // keep-alive: 6 one-query clients + 1 client reusing a single
    // connection for /datasets and /stats
    assert_eq!(st.accepted, 7, "{st}");
    assert_eq!(st.keepalive_reuse, 1, "{st}");
    assert_eq!(st.active_conns, 0, "{st}");
}

#[test]
fn server_survives_protocol_abuse_then_serves() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);
    let (server, _store, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 2,
            queue: 8,
            ..ServerConfig::default()
        },
    );

    // malformed request line
    let r = raw(&addr, b"NONSENSE\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");
    // oversized head (default cap 8 KiB)
    let big = format!(
        "GET /query?dataset={} HTTP/1.1\r\n\r\n",
        "x".repeat(20_000)
    );
    let r = raw(&addr, big.as_bytes());
    assert!(r.starts_with("HTTP/1.1 431"), "{r}");
    // wrong method / unknown endpoint
    let r = raw(&addr, b"POST /query HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 405"), "{r}");
    let r = raw(&addr, b"GET /nothing HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 404"), "{r}");
    // missing dataset parameter
    let r = raw(&addr, b"GET /query HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 400"), "{r}");

    // typed client-side errors carry the status and the server's message
    let client = QueryClient::new(addr.clone());
    let err = client.query("nope", None, None, "").unwrap_err().to_string();
    assert!(err.contains("404"), "{err}");
    let err = client.query("hcci", Some(6), Some(2), "").unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");
    let err = client
        .query("hcci", None, None, "not_a_species")
        .unwrap_err()
        .to_string();
    assert!(err.contains("400"), "{err}");
    let err = client
        .query("hcci", Some(0), Some(999), "")
        .unwrap_err()
        .to_string();
    assert!(err.contains("400"), "{err}");

    // after all the abuse, the same workers serve a correct response —
    // defaults resolve to the full axis and all species
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let dec = client.query("hcci", None, None, "").unwrap();
    assert_eq!((dec.t0, dec.nt), (0, 8));
    assert_eq!(dec.species, vec![0, 1, 2, 3]);
    let oracle = comp.extract(&SliceSource(&bytes), 0, 8, &[], 1).unwrap();
    for (a, b) in dec.mass.iter().zip(&oracle.mass) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    let st = server.shutdown();
    assert_eq!(st.served, 1, "{st}");
    assert!(st.client_errors >= 9, "{st}");
    assert_eq!(st.server_errors, 0, "{st}");
    // 5 raw abuse connections + the typed client's single keep-alive
    // connection carrying all 5 of its requests (4 errors + 1 hit)
    assert_eq!(st.accepted, 6, "{st}");
    assert_eq!(st.keepalive_reuse, 4, "{st}");
    assert_eq!(client.connections_opened(), 1);
}

// ---- observability pipeline ----------------------------------------

/// First u64 after `"key":` in `json` (panics if absent) — enough for
/// the hand-rolled trace/metrics formats these tests cover.
fn field_u64(json: &str, key: &str) -> u64 {
    let pat = format!("\"{key}\":");
    let at = json.find(&pat).unwrap_or_else(|| panic!("missing {key} in {json}"));
    json[at + pat.len()..]
        .chars()
        .take_while(|c| c.is_ascii_digit())
        .collect::<String>()
        .parse()
        .unwrap_or_else(|_| panic!("bad {key} in {json}"))
}

/// The one span object with this trace id, sliced out of `/trace/slow`.
fn span_slice<'a>(slow: &'a str, trace_id: &str) -> &'a str {
    let pat = format!("\"trace_id\":\"{trace_id}\"");
    let at = slow.find(&pat).unwrap_or_else(|| panic!("span {trace_id} missing from {slow}"));
    let rest = &slow[at..];
    match rest[pat.len()..].find("\"trace_id\":") {
        Some(next) => &rest[..pat.len() + next],
        None => rest,
    }
}

/// `(name, start_ns, dur_ns)` for every phase present in a span slice.
fn span_phases(span: &str) -> Vec<(&'static str, u64, u64)> {
    let names = [
        "parse",
        "queue_wait",
        "cache_probe",
        "decode",
        "salvage",
        "serialize",
        "write",
    ];
    let mut out = Vec::new();
    for name in names {
        let pat = format!("\"{name}\":{{");
        if let Some(at) = span.find(&pat) {
            let obj = &span[at..];
            out.push((name, field_u64(obj, "start_ns"), field_u64(obj, "dur_ns")));
        }
    }
    out
}

#[test]
fn tracing_spans_and_metrics_pipeline() {
    let service = ExecService::start_reference(small_spec(), 4).unwrap();
    let handle = service.handle();
    let bytes = build_archive(&handle, 8);
    let (server, _store, addr) = start_server(
        &handle,
        &bytes,
        ServerConfig {
            workers: 2,
            queue: 8,
            trace_sample: 1, // trace every request
            ..ServerConfig::default()
        },
    );

    // every 200 carries a 16-hex X-Gbatc-Trace-Id, and the ids are unique
    let client = QueryClient::new(addr.clone());
    let mut ids: Vec<String> = Vec::new();
    for t0 in 0..4usize {
        let dec = client.query("hcci", Some(t0), Some(t0 + 4), "1").unwrap();
        assert!(!dec.mass.is_empty());
        let id = dec.trace_id.clone().expect("200 without X-Gbatc-Trace-Id");
        assert_eq!(id.len(), 16, "{id}");
        assert!(id.chars().all(|c| c.is_ascii_hexdigit()), "{id}");
        ids.push(id);
    }
    let mut uniq = ids.clone();
    uniq.sort();
    uniq.dedup();
    assert_eq!(uniq.len(), ids.len(), "trace ids must be unique: {ids:?}");

    // routed errors carry the header too (it is attached per response,
    // not per success), and land in the error counters below
    let r = raw(&addr, b"GET /nothing HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert!(r.starts_with("HTTP/1.1 404"), "{r}");
    assert!(r.to_ascii_lowercase().contains("x-gbatc-trace-id:"), "{r}");
    let err = client.query("hcci", Some(6), Some(2), "").unwrap_err().to_string();
    assert!(err.contains("400"), "{err}");

    // every traced query shows up in /trace/slow with phase timings that
    // are monotone, non-overlapping, and contained in the span total
    let slow = client.trace_slow_json(64).unwrap();
    assert!(field_u64(&slow, "recorded") >= ids.len() as u64, "{slow}");
    for id in &ids {
        let span = span_slice(&slow, id);
        assert!(span.contains("\"target\":\"/query?dataset=hcci"), "{span}");
        assert!(span.contains("\"status\":200"), "{span}");
        let total = field_u64(span, "total_ns");
        let mut phases = span_phases(span);
        assert!(
            phases.iter().any(|p| p.0 == "serialize"),
            "span without a serialize phase: {span}"
        );
        assert!(
            phases.iter().any(|p| p.0 == "cache_probe" || p.0 == "decode"),
            "span without store phases: {span}"
        );
        phases.sort_by_key(|&(_, start, _)| start);
        let mut prev_end = 0u64;
        for (name, start, dur) in phases {
            assert!(
                start >= prev_end,
                "{name} starts at {start} inside the previous phase (ends {prev_end}): {span}"
            );
            let end = start + dur;
            assert!(end <= total, "{name} ends at {end}, past total {total}: {span}");
            prev_end = end;
        }
    }

    // /metrics is well-formed Prometheus text: comments aside, every
    // line is `series value` with a parseable value, and the query
    // histogram's +Inf bucket equals its _count
    let metrics = client.metrics_text().unwrap();
    for line in metrics.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (_, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line: {line}"));
        assert!(value.parse::<f64>().is_ok(), "unparsable value: {line}");
    }
    for family in [
        "gbatc_query_seconds",
        "gbatc_queue_wait_seconds",
        "gbatc_decode_seconds",
        "gbatc_cache_probe_seconds",
    ] {
        assert!(metrics.contains(&format!("# TYPE {family} histogram")), "{metrics}");
    }
    let inf = format!("gbatc_query_seconds_bucket{{le=\"+Inf\"}} ");
    let inf_count: u64 = metrics
        .lines()
        .find(|l| l.starts_with(&inf))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .expect("query histogram +Inf bucket");
    let count_line = metrics
        .lines()
        .find(|l| l.starts_with("gbatc_query_seconds_count "))
        .expect("query histogram count");
    assert_eq!(
        inf_count,
        count_line.rsplit(' ').next().and_then(|v| v.parse().ok()).unwrap_or(0),
        "{metrics}"
    );
    assert!(metrics.contains("gbatc_responses_total{class=\"2xx\"}"), "{metrics}");
    assert!(metrics.contains("gbatc_trace_spans_total{outcome=\"recorded\"}"), "{metrics}");

    // counter-vs-histogram consistency: the latency histogram sees one
    // sample per routed response, exactly the status-class counter sum
    // (runs in both server modes via the GBATC_NO_EPOLL CI leg)
    let snap = server.obs().query_latency();
    let stats = client.stats_json().unwrap();
    assert!(field_u64(&stats, "bytes_out") > 0, "{stats}");
    let st = server.shutdown();
    // the /stats request above happened after the snapshot
    assert_eq!(
        snap.count + 1,
        st.served + st.client_errors + st.server_errors,
        "histogram count must equal routed responses: {st}"
    );
    assert!(st.bytes_out > 0, "{st}");
    assert_eq!(st.server_errors, 0, "{st}");
}
