//! Rate–distortion planner tests over the reference runtime: `--codec
//! auto` must never produce more bytes than either single-codec run at
//! the same NRMSE target (beyond the v3 TOC tag overhead), every
//! (shard, species) NRMSE must stay certified, and mixed-codec `GBA2`
//! archives must partial-decode bit-identically to their full decode.

use gbatc::archive::{AnyArchive, CodecTag, CountingSource, Gba2Archive, ShardPayload, SliceSource};
use gbatc::compressor::registry::{SectionCodec, SectionView, DENSE_STAGE, SZ_STAGE};
use gbatc::compressor::{CodecChoice, CompressOptions, GbatcCompressor};
use gbatc::data::Dataset;
use gbatc::runtime::{ExecService, RuntimeSpec};

const NS: usize = 2;
const NY: usize = 40;
const NX: usize = 40;

fn spec() -> RuntimeSpec {
    RuntimeSpec {
        species: NS,
        block: (4, 5, 4),
        latent: 6,
        batch: 8,
        points: 64,
    }
}

/// Species 0 is a smooth low-frequency field (SZ-friendly); species 1 is
/// a high-frequency checkerboard under a slowly drifting amplitude
/// (structured — the pooled reference AE leaves a low-rank residual).
fn make_ds(nt: usize) -> Dataset {
    let mut ds = Dataset::new(nt, NS, NY, NX);
    for t in 0..nt {
        for y in 0..NY {
            for x in 0..NX {
                let smooth = 0.5
                    + 0.3 * ((t as f32) * 0.25 + (y as f32) * 0.07 + (x as f32) * 0.05).sin();
                let sign = if (t + y + x) % 2 == 0 { 1.0f32 } else { -1.0 };
                let amp = 0.2 + 0.05 * ((t as f32) * 0.3 + (y as f32) * 0.02).cos();
                let i0 = ds.idx(t, 0, y, x);
                ds.mass[i0] = smooth;
                let i1 = ds.idx(t, 1, y, x);
                ds.mass[i1] = 0.5 + sign * amp;
            }
        }
    }
    ds
}

fn opts(codec: CodecChoice) -> CompressOptions {
    CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 8,
        threads: 2,
        shard_workers: 1,
        codec,
        ..Default::default()
    }
}

/// Per-(shard window, species) NRMSE of `recon` against `ds`, normalized
/// by the global species range (the units the engine certifies).
fn section_nrmse(ds: &Dataset, recon: &[f32], t0: usize, t1: usize, s: usize) -> f64 {
    let ranges = ds.species_ranges();
    let range = (ranges[s].1 - ranges[s].0).max(1e-30) as f64;
    let npix = ds.ny * ds.nx;
    let mut se = 0.0f64;
    let mut n = 0usize;
    for t in t0..t1 {
        let off = (t * ds.ns + s) * npix;
        for p in 0..npix {
            let e = (ds.mass[off + p] - recon[off + p]) as f64 / range;
            se += e * e;
            n += 1;
        }
    }
    (se / n as f64).sqrt()
}

fn assert_range_matches_full(
    comp: &GbatcCompressor<'_>,
    archive: &Gba2Archive,
    full: &[f32],
    t0: usize,
    t1: usize,
    sel: &[usize],
) {
    let src = SliceSource(&archive.bytes);
    let out = comp.extract(&src, t0, t1, sel, 2).unwrap();
    let npix = NY * NX;
    assert_eq!(out.mass.len(), (t1 - t0) * sel.len() * npix);
    for t in t0..t1 {
        for (k, &s) in sel.iter().enumerate() {
            for p in 0..npix {
                let a = full[(t * NS + s) * npix + p];
                let b = out.mass[((t - t0) * sel.len() + k) * npix + p];
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} s={s} p={p}: {a} vs {b}");
            }
        }
    }
}

#[test]
fn planner_never_worse_than_single_codec_and_certifies() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let ds = make_ds(16);
    let target = 1e-3;

    let auto = comp.compress(&ds, &opts(CodecChoice::Auto)).unwrap();
    let gbatc = comp.compress(&ds, &opts(CodecChoice::Gbatc)).unwrap();
    let sz = comp.compress(&ds, &opts(CodecChoice::Sz)).unwrap();

    let n_shards = auto.archive.n_shards();
    assert_eq!(n_shards, 2);
    let tag_overhead = n_shards * NS + 64;
    let auto_bytes = auto.archive.payload_bytes();
    let best_single = gbatc.archive.payload_bytes().min(sz.archive.payload_bytes());
    eprintln!(
        "auto {auto_bytes} B vs gbatc {} B / sz {} B; tags: {:?} {:?}",
        gbatc.archive.payload_bytes(),
        sz.archive.payload_bytes(),
        auto.archive.toc[0].codecs,
        auto.archive.toc[1].codecs,
    );
    assert!(
        auto_bytes <= best_single + tag_overhead,
        "auto {auto_bytes} B > min single-codec {best_single} B + {tag_overhead}"
    );
    // the bound also holds with the model-parameter charge included (the
    // archive-level planner is model-aware)
    let auto_total = auto.archive.total_bytes();
    let best_total = gbatc.archive.total_bytes().min(sz.archive.total_bytes());
    assert!(
        auto_total <= best_total + tag_overhead,
        "auto total {auto_total} B > min single-codec total {best_total} B + {tag_overhead}"
    );

    // every (shard, species) NRMSE of the planner archive stays certified
    let full = comp.decompress(&auto.archive, 2).unwrap();
    for entry in &auto.archive.toc {
        for s in 0..NS {
            let nrmse = section_nrmse(&ds, &full, entry.t0, entry.t0 + entry.nt, s);
            assert!(
                nrmse <= target * 1.05,
                "shard t0 {} species {s} ({:?}): NRMSE {nrmse} > {target}",
                entry.t0,
                entry.codecs[s]
            );
        }
    }

    // partial decode of the planner archive is bit-identical to the full
    // decode, across the shard boundary and per species
    assert_range_matches_full(&comp, &auto.archive, &full, 6, 10, &[0, 1]);
    assert_range_matches_full(&comp, &auto.archive, &full, 0, 8, &[1]);
    assert_range_matches_full(&comp, &auto.archive, &full, 8, 16, &[0]);
}

#[test]
fn all_sz_gba2_archive_is_model_free_and_partial_decodes() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let ds = make_ds(16);
    let target = 1e-3;

    let report = comp.compress(&ds, &opts(CodecChoice::Sz)).unwrap();
    let archive = report.archive;
    assert_eq!(archive.version(), 3);
    assert_eq!(archive.header.model_param_bytes, 0);
    for entry in &archive.toc {
        assert!(entry.codecs.iter().all(|&c| c == CodecTag::Sz));
        // no shared latent plane is stored for model-free shards
        assert_eq!(entry.latent.1, 0);
    }

    let full = comp.decompress(&archive, 2).unwrap();
    for entry in &archive.toc {
        for s in 0..NS {
            let nrmse = section_nrmse(&ds, &full, entry.t0, entry.t0 + entry.nt, s);
            assert!(nrmse <= target * 1.05, "species {s}: NRMSE {nrmse}");
        }
    }

    // partial decode touches strictly fewer bytes and matches bit-for-bit
    let src = SliceSource(&archive.bytes);
    let counting = CountingSource::new(&src);
    let out = comp.extract(&counting, 8, 12, &[1], 2).unwrap();
    let npix = NY * NX;
    for t in 8..12usize {
        for p in 0..npix {
            let a = full[(t * NS + 1) * npix + p];
            let b = out.mass[(t - 8) * npix + p];
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    assert!(counting.bytes_read() * 2 < archive.bytes.len() as u64);

    // the version-3 container round-trips through the dispatching reader
    let any = AnyArchive::deserialize(&archive.bytes).unwrap();
    assert_eq!(any.version(), 3);
    assert_eq!(any.into_v2().unwrap().serialize(), archive.bytes);
}

#[test]
fn hand_spliced_mixed_archive_partial_decode_bit_identical() {
    let service = ExecService::start_reference(spec(), 4).unwrap();
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let ds = make_ds(16);
    let target = 1e-3;

    let report = comp.compress(&ds, &opts(CodecChoice::Gbatc)).unwrap();
    let base = report.archive;
    assert_eq!(base.version(), 2);
    assert_eq!(base.n_shards(), 2);

    // re-encode (shard 0, species 1) with the SZ stage and (shard 1,
    // species 0) with the dense stage, from the same normalized planes the
    // engine used — a deterministic, guaranteed-mixed archive
    let ranges = ds.species_ranges();
    let norm = gbatc::compressor::gba::normalize_mass(&ds, &ranges, 2);
    let npix = NY * NX;
    let budget = target * 0.999;
    let plane_of = |t0: usize, nt: usize, s: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(nt * npix);
        for t in t0..t0 + nt {
            let off = (t * NS + s) * npix;
            out.extend_from_slice(&norm[off..off + npix]);
        }
        out
    };

    let mut shards = Vec::new();
    for (i, entry) in base.toc.iter().enumerate() {
        let mut species: Vec<Vec<u8>> = (0..NS)
            .map(|s| base.species_bytes(i, s).unwrap().to_vec())
            .collect();
        let mut codecs = vec![CodecTag::Gbatc; NS];
        let (stage, s): (&dyn SectionCodec, usize) =
            if i == 0 { (&SZ_STAGE, 1) } else { (&DENSE_STAGE, 0) };
        let plane = plane_of(entry.t0, entry.nt, s);
        let sv = SectionView {
            species: s,
            nt: entry.nt,
            ny: NY,
            nx: NX,
            norm: &plane,
        };
        let enc = stage
            .encode(&sv, budget)
            .unwrap()
            .expect("stage certifies on synthetic plane");
        species[s] = enc.bytes;
        codecs[s] = enc.tag;
        shards.push(ShardPayload {
            t0: entry.t0,
            nt: entry.nt,
            latent_blob: base.latent_bytes(i).unwrap().to_vec(),
            species,
            codecs,
        });
    }
    let mixed = Gba2Archive::build(base.header.clone(), shards).unwrap();
    assert_eq!(mixed.version(), 3);

    // the spliced sections still certify their per-section NRMSE, and the
    // untouched GBATC sections decode as before
    let full = comp.decompress(&mixed, 2).unwrap();
    for entry in &mixed.toc {
        for s in 0..NS {
            let nrmse = section_nrmse(&ds, &full, entry.t0, entry.t0 + entry.nt, s);
            assert!(
                nrmse <= target * 1.05,
                "shard t0 {} species {s} ({:?}): NRMSE {nrmse}",
                entry.t0,
                entry.codecs[s]
            );
        }
    }

    // partial decode == full decode, bit for bit, on the mixed container
    assert_range_matches_full(&comp, &mixed, &full, 6, 10, &[0, 1]);
    assert_range_matches_full(&comp, &mixed, &full, 0, 4, &[1]);
    assert_range_matches_full(&comp, &mixed, &full, 12, 16, &[0]);
}
