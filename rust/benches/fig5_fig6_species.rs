//! Figures 5 & 6 reproduction: per-species reconstruction quality at the
//! paper's working point — temporal snapshots (first / middle / last frame)
//! of the mass fraction (PD) and formation rate (QoI) for a *major* species
//! (H2O, Fig. 5) and a *minor* radical (C2H3, Fig. 6), for GBATC / GBA /
//! SZ, quantified with SSIM and PSNR as the paper does.
//!
//! Paper reference: at CR 400 all methods look visually identical on H2O;
//! on C2H3's QoI, SZ shows visible discrepancy while GBATC/GBA stay
//! accurate; SSIM/PSNR order GBATC >= GBA > SZ.

#[path = "common.rs"]
mod common;

use common::*;
use gbatc::chem;
use gbatc::metrics::{psnr_with_range, ssim2d_with_range};

fn main() {
    let env = BenchEnv::new(1234);
    let handle = env.handle();
    let ds = &env.ds;
    // paper's working point: the accuracy domain experts recommend
    let target = 1e-3;

    eprintln!("[bench] compressing with GBATC/GBA/SZ @ {target:.0e}...");
    let (cr_tc, recon_tc) = run_gbatc(&env, &handle, target, true);
    let (cr_gb, recon_gb) = run_gbatc(&env, &handle, target, false);
    let (cr_sz, recon_sz) = run_sz(&env, target, 1.0);
    println!(
        "== Figs 5/6: species snapshots @ target {target:.0e} (CR: GBATC {cr_tc:.0}, GBA {cr_gb:.0}, SZ {cr_sz:.0})"
    );

    let frames = [0usize, ds.nt / 2, ds.nt - 1];
    let stride = 2; // QoI frames computed on strided grid
    let methods: [(&str, &Vec<f32>); 3] =
        [("GBATC", &recon_tc), ("GBA", &recon_gb), ("SZ", &recon_sz)];

    for (fig, name) in [("Fig 5 (major)", "H2O"), ("Fig 6 (minor)", "C2H3")] {
        let s = chem::index_of(name).unwrap();
        // species-wide dynamic ranges for PD and QoI (per-frame ranges
        // collapse pre/post-ignition and make the metric meaningless)
        let ranges = ds.species_ranges();
        let pd_range = (ranges[s].1 - ranges[s].0) as f64;
        println!("\n-- {fig}: {name} --");
        println!(
            "{:<7} {:>6} {:>12} {:>10} {:>12} {:>10}",
            "method", "frame", "PD SSIM", "PD PSNR", "QoI SSIM", "QoI PSNR"
        );
        for (mname, recon) in &methods {
            // QoI sampled fields for this method (all frames at once)
            let (qo, qr, npts) = qoi_fields(ds, recon, stride);
            let pts_per_frame = npts / ds.nt;
            let qny = ds.ny.div_ceil(stride);
            let qnx = ds.nx.div_ceil(stride);
            assert_eq!(pts_per_frame, qny * qnx);
            for &t in &frames {
                let orig_frame = ds.species_frame(t, s);
                let npix = ds.ny * ds.nx;
                let off = (t * ds.ns + s) * npix;
                let rec_frame = &recon[off..off + npix];
                let pd_ssim = ssim2d_with_range(orig_frame, rec_frame, ds.ny, ds.nx, pd_range);
                let pd_psnr = psnr_with_range(orig_frame, rec_frame, pd_range);

                let qoff = s * npts + t * pts_per_frame;
                let qof: Vec<f32> = qo[qoff..qoff + pts_per_frame]
                    .iter()
                    .map(|&v| v as f32)
                    .collect();
                let qrf: Vec<f32> = qr[qoff..qoff + pts_per_frame]
                    .iter()
                    .map(|&v| v as f32)
                    .collect();
                let qoi_all = &qo[s * npts..(s + 1) * npts];
                let q_range = qoi_all.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
                    - qoi_all.iter().cloned().fold(f64::INFINITY, f64::min);
                let q_ssim = ssim2d_with_range(&qof, &qrf, qny, qnx, q_range);
                let q_psnr = psnr_with_range(&qof, &qrf, q_range);
                println!(
                    "{:<7} {:>6} {:>12.5} {:>10.1} {:>12.5} {:>10.1}",
                    mname, t, pd_ssim, pd_psnr, q_ssim, q_psnr
                );
            }
        }
    }
    println!("\npaper shape: GBATC >= GBA > SZ on SSIM/PSNR, gap largest on minor-species QoI");
}
