//! Codec-planner benchmark: one synthetic field compressed three ways —
//! all-GBATC, all-SZ, and the rate–distortion planner (`auto`) — on the
//! pure-Rust reference backend, reporting bytes / ratio / wall time and
//! writing a machine-readable `BENCH_planner.json` artifact so CI can
//! accumulate the perf trajectory:
//!
//! ```bash
//! cargo bench --bench perf_codec_planner
//! GBATC_BENCH_PROFILE=small GBATC_BENCH_OUT=out.json cargo bench --bench perf_codec_planner
//! ```

use gbatc::compressor::{CodecChoice, CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Profile};
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::util::Timer;

struct Row {
    name: &'static str,
    bytes: usize,
    ratio: f64,
    wall_s: f64,
    codec_sections: [usize; 3],
}

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let kt_window: usize = std::env::var("GBATC_KT_WINDOW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let out_path =
        std::env::var("GBATC_BENCH_OUT").unwrap_or_else(|_| "BENCH_planner.json".to_string());

    eprintln!("[bench] generating {profile:?} dataset...");
    let ds = generate(profile, 42);
    let pd = ds.pd_bytes();
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);

    println!(
        "== perf_codec_planner ({}x{}x{}x{}, kt_window {kt_window})",
        ds.nt, ds.ns, ds.ny, ds.nx
    );
    let mut rows: Vec<Row> = Vec::new();
    for (name, codec) in [
        ("gbatc", CodecChoice::Gbatc),
        ("sz", CodecChoice::Sz),
        ("auto", CodecChoice::Auto),
    ] {
        let opts = CompressOptions {
            nrmse_target: 1e-3,
            kt_window,
            codec,
            ..Default::default()
        };
        let t = Timer::start();
        let report = comp.compress(&ds, &opts).expect("compress");
        let wall_s = t.secs();
        let bytes = report.archive.total_bytes();
        let ratio = pd as f64 / bytes as f64;
        let totals = report.archive.codec_totals();
        let codec_sections = [totals[0].0, totals[1].0, totals[2].0];
        println!(
            "{name:>6}  {bytes:>10} B  CR {ratio:>6.1}  {wall_s:>7.2}s  sections G/S/D {}/{}/{}",
            codec_sections[0], codec_sections[1], codec_sections[2]
        );
        rows.push(Row {
            name,
            bytes,
            ratio,
            wall_s,
            codec_sections,
        });
    }

    // hand-rolled JSON (no serde in the offline image)
    let mut json = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"name\": \"{}\", \"bytes\": {}, \"ratio\": {:.3}, \"wall_time_s\": {:.4}, \
             \"sections_gbatc\": {}, \"sections_sz\": {}, \"sections_dense\": {}}}{}\n",
            r.name,
            r.bytes,
            r.ratio,
            r.wall_s,
            r.codec_sections[0],
            r.codec_sections[1],
            r.codec_sections[2],
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    std::fs::write(&out_path, &json).expect("write bench json");
    println!("wrote {out_path}");

    // the planner must never lose to either single-codec run by more than
    // the v3 TOC tag overhead — fail the bench loudly if it regresses
    let auto = rows.iter().find(|r| r.name == "auto").unwrap().bytes;
    let best = rows
        .iter()
        .filter(|r| r.name != "auto")
        .map(|r| r.bytes)
        .min()
        .unwrap();
    let kt = kt_window.max(1);
    let n_shards = (0..ds.nt).step_by(kt).count();
    let overhead = ds.ns * n_shards + 64;
    assert!(
        auto <= best + overhead,
        "planner regression: auto {auto} B > best single {best} B + {overhead}"
    );
}
