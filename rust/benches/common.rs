//! Shared bench harness code (no criterion in the offline image; each bench
//! is a `harness = false` binary printing the paper-figure tables).

#![allow(dead_code)]

use gbatc::chem::{self, Mechanism};
use gbatc::compressor::{CompressOptions, GbatcCompressor, SzCompressOptions, SzCompressor};
use gbatc::config::Manifest;
use gbatc::coordinator::scheduler::par_for;
use gbatc::data::{generate, Dataset, Profile};
use gbatc::metrics;
use gbatc::runtime::{ExecHandle, ExecService};
use std::sync::Mutex;

/// Bench dataset profile: GBATC_BENCH_PROFILE=tiny|small|medium (default small).
pub fn bench_profile() -> Profile {
    let name = std::env::var("GBATC_BENCH_PROFILE").unwrap_or_else(|_| "small".into());
    Profile::parse(&name).expect("bad GBATC_BENCH_PROFILE")
}

pub fn artifacts_dir() -> String {
    std::env::var("GBATC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

pub struct BenchEnv {
    pub service: ExecService,
    pub manifest: Manifest,
    pub ds: Dataset,
}

impl BenchEnv {
    pub fn new(seed: u64) -> BenchEnv {
        let profile = bench_profile();
        eprintln!("[bench] generating {profile:?} dataset (seed {seed})...");
        let ds = generate(profile, seed);
        let service = ExecService::start(&artifacts_dir(), 4).expect("artifacts missing — run `make artifacts`");
        let manifest = Manifest::load(format!("{}/manifest.txt", artifacts_dir())).unwrap();
        BenchEnv { service, manifest, ds }
    }

    pub fn handle(&self) -> ExecHandle {
        self.service.handle()
    }

    pub fn compressor<'a>(&self, handle: &'a ExecHandle) -> GbatcCompressor<'a> {
        GbatcCompressor::new(handle, self.manifest.decoder_params, self.manifest.tcn_params)
    }
}

/// Per-species + mean NRMSE between `[T,S,Y,X]` mass arrays.
pub fn species_nrmse(ds: &Dataset, recon: &[f32]) -> (Vec<f64>, f64) {
    let npix = ds.ny * ds.nx;
    let per: Vec<f64> = (0..ds.ns)
        .map(|s| {
            let mut o = Vec::with_capacity(ds.nt * npix);
            let mut r = Vec::with_capacity(ds.nt * npix);
            for t in 0..ds.nt {
                let off = (t * ds.ns + s) * npix;
                o.extend_from_slice(&ds.mass[off..off + npix]);
                r.extend_from_slice(&recon[off..off + npix]);
            }
            metrics::nrmse(&o, &r)
        })
        .collect();
    let mean = per.iter().sum::<f64>() / per.len() as f64;
    (per, mean)
}

/// Sampled production-rate fields for orig and recon: returns
/// (qoi_orig, qoi_recon) species-major `[S, n]` plus n, for the sampled
/// points (all t, strided y/x), computed in parallel.
pub fn qoi_fields(ds: &Dataset, recon: &[f32], stride: usize) -> (Vec<f64>, Vec<f64>, usize) {
    let mech = Mechanism::standard();
    let ns = ds.ns;
    let mut idxs = Vec::new();
    for t in 0..ds.nt {
        for y in (0..ds.ny).step_by(stride) {
            for x in (0..ds.nx).step_by(stride) {
                idxs.push((t, y, x));
            }
        }
    }
    let n = idxs.len();
    let mut ys_o = vec![0.0f32; ns * n];
    let mut ys_r = vec![0.0f32; ns * n];
    let mut temps = vec![0.0f32; n];
    for (i, &(t, y, x)) in idxs.iter().enumerate() {
        temps[i] = ds.temp_at(t, y, x);
        for s in 0..ns {
            let off = ((t * ns + s) * ds.ny + y) * ds.nx + x;
            ys_o[s * n + i] = ds.mass[off];
            ys_r[s * n + i] = recon[off];
        }
    }
    // parallel over point chunks
    let threads = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    let chunk = n.div_ceil(threads * 4).max(1);
    let n_chunks = n.div_ceil(chunk);
    let wo: Vec<Mutex<Vec<f64>>> = (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
    let wr: Vec<Mutex<Vec<f64>>> = (0..n_chunks).map(|_| Mutex::new(Vec::new())).collect();
    par_for(n_chunks, threads, |c| {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        let m = hi - lo;
        let mut yo = vec![0.0f32; ns * m];
        let mut yr = vec![0.0f32; ns * m];
        for s in 0..ns {
            yo[s * m..(s + 1) * m].copy_from_slice(&ys_o[s * n + lo..s * n + hi]);
            yr[s * m..(s + 1) * m].copy_from_slice(&ys_r[s * n + lo..s * n + hi]);
        }
        let mut oo = vec![0.0f64; ns * m];
        let mut or = vec![0.0f64; ns * m];
        chem::production_rates(&mech, &yo, &temps[lo..hi], ds.pressure, m, &mut oo);
        chem::production_rates(&mech, &yr, &temps[lo..hi], ds.pressure, m, &mut or);
        *wo[c].lock().unwrap() = oo;
        *wr[c].lock().unwrap() = or;
    });
    let mut qo = vec![0.0f64; ns * n];
    let mut qr = vec![0.0f64; ns * n];
    for c in 0..n_chunks {
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        let m = hi - lo;
        let oo = wo[c].lock().unwrap();
        let or = wr[c].lock().unwrap();
        for s in 0..ns {
            qo[s * n + lo..s * n + hi].copy_from_slice(&oo[s * m..(s + 1) * m]);
            qr[s * n + lo..s * n + hi].copy_from_slice(&or[s * m..(s + 1) * m]);
        }
    }
    (qo, qr, n)
}

/// (per-species, mean) QoI NRMSE.
pub fn qoi_nrmse(ds: &Dataset, recon: &[f32], stride: usize) -> (Vec<f64>, f64) {
    let (qo, qr, _) = qoi_fields(ds, recon, stride);
    metrics::nrmse::nrmse_per_species_f64(&qo, &qr, ds.ns)
}

/// One (method, CR, PD, QoI) result row.
pub struct Row {
    pub method: &'static str,
    pub target: f64,
    pub cr: f64,
    pub pd: f64,
    pub qoi: f64,
}

pub fn print_rows(rows: &[Row]) {
    println!(
        "{:<8} {:>9} {:>10} {:>12} {:>12}",
        "method", "target", "CR", "PD NRMSE", "QoI NRMSE"
    );
    for r in rows {
        println!(
            "{:<8} {:>9.0e} {:>10.1} {:>12.3e} {:>12.3e}",
            r.method, r.target, r.cr, r.pd, r.qoi
        );
    }
}

/// Run GBATC or GBA at a target; returns (report CR, recon mass).
pub fn run_gbatc(
    env: &BenchEnv,
    handle: &ExecHandle,
    target: f64,
    use_tcn: bool,
) -> (f64, Vec<f32>) {
    let comp = env.compressor(handle);
    let opts = CompressOptions {
        nrmse_target: target,
        use_tcn,
        ..Default::default()
    };
    let report = comp.compress(&env.ds, &opts).unwrap();
    assert!(report.max_block_residual <= report.tau + 1e-9);
    let recon = comp.decompress(&report.archive, 0).unwrap();
    (report.archive.compression_ratio(), recon)
}

/// Run SZ at a target; returns (CR, recon mass).
pub fn run_sz(env: &BenchEnv, target: f64, eb_scale: f64) -> (f64, Vec<f32>) {
    let szc = SzCompressor::new(SzCompressOptions {
        eb_scale,
        ..Default::default()
    });
    let archive = szc.compress(&env.ds, target).unwrap();
    let recon = szc.decompress(&archive).unwrap();
    (
        env.ds.pd_bytes() as f64 / archive.total_bytes() as f64,
        recon,
    )
}
