//! Figures 7 & 8 reproduction: temporal evolution of the spatial mean and
//! standard deviation of mass fractions (PD) and formation rates (QoI) for
//! the major species H2O / CO / CO2 (Fig. 7) and the low-temperature minor
//! nC3H7COCH2 (Fig. 8), as predicted by DNS (original) vs GBATC / GBA / SZ
//! at the paper's working point.
//!
//! Paper reference: majors agree for all methods; for the minor species,
//! SZ shows noticeable error in QoI mean/std while GBATC tracks the DNS.

#[path = "common.rs"]
mod common;

use common::*;
use gbatc::chem;
use gbatc::metrics::stats::{frame_mean_std, temporal_profiles_f64};

fn main() {
    let env = BenchEnv::new(1234);
    let handle = env.handle();
    let ds = &env.ds;
    let target = 1e-3;

    eprintln!("[bench] compressing with GBATC/GBA/SZ @ {target:.0e}...");
    let (_, recon_tc) = run_gbatc(&env, &handle, target, true);
    let (_, recon_gb) = run_gbatc(&env, &handle, target, false);
    let (_, recon_sz) = run_sz(&env, target, 1.0);
    let methods: [(&str, &Vec<f32>); 3] =
        [("GBATC", &recon_tc), ("GBA", &recon_gb), ("SZ", &recon_sz)];

    let stride = 2;
    println!("== Figs 7/8: temporal mean/std profiles @ target {target:.0e}");

    for (fig, names) in [
        ("Fig 7 (majors)", vec!["H2O", "CO", "CO2"]),
        ("Fig 8 (minor)", vec!["nC3H7COCH2"]),
    ] {
        for name in names {
            let s = chem::index_of(name).unwrap();
            println!("\n-- {fig}: {name} --");

            // PD profiles
            println!("PD mass fraction (mean/std per frame):");
            println!(
                "{:>4} {:>13} {:>13} | {:>12} {:>12} {:>12} | {:>12} {:>12} {:>12}",
                "t", "DNS mean", "DNS std", "dTC mean%", "dGBA mean%", "dSZ mean%",
                "dTC std%", "dGBA std%", "dSZ std%"
            );
            let npix = ds.ny * ds.nx;
            for t in 0..ds.nt {
                let (m0, s0) = frame_mean_std(ds.species_frame(t, s));
                let mut devs_m = Vec::new();
                let mut devs_s = Vec::new();
                for (_, recon) in &methods {
                    let off = (t * ds.ns + s) * npix;
                    let (m, sd) = frame_mean_std(&recon[off..off + npix]);
                    devs_m.push(100.0 * (m - m0) / m0.abs().max(1e-300));
                    devs_s.push(100.0 * (sd - s0) / s0.abs().max(1e-300));
                }
                println!(
                    "{:>4} {:>13.4e} {:>13.4e} | {:>12.4} {:>12.4} {:>12.4} | {:>12.4} {:>12.4} {:>12.4}",
                    t, m0, s0, devs_m[0], devs_m[1], devs_m[2], devs_s[0], devs_s[1], devs_s[2]
                );
            }

            // QoI profiles (formation rate, strided sample)
            println!("QoI formation rate (relative profile deviation, % max over frames):");
            let mut summary = Vec::new();
            for (mname, recon) in &methods {
                let (qo, qr, npts) = qoi_fields(ds, recon, stride);
                let per_frame = npts / ds.nt;
                let prof_o = temporal_profiles_f64(&qo[s * npts..(s + 1) * npts], ds.nt);
                let prof_r = temporal_profiles_f64(&qr[s * npts..(s + 1) * npts], ds.nt);
                let scale_m = prof_o
                    .iter()
                    .map(|&(m, _)| m.abs())
                    .fold(0.0f64, f64::max)
                    .max(1e-300);
                let scale_s = prof_o
                    .iter()
                    .map(|&(_, sd)| sd.abs())
                    .fold(0.0f64, f64::max)
                    .max(1e-300);
                let dev_m = prof_o
                    .iter()
                    .zip(&prof_r)
                    .map(|(&(a, _), &(b, _))| (a - b).abs() / scale_m)
                    .fold(0.0f64, f64::max);
                let dev_s = prof_o
                    .iter()
                    .zip(&prof_r)
                    .map(|(&(_, a), &(_, b))| (a - b).abs() / scale_s)
                    .fold(0.0f64, f64::max);
                println!(
                    "  {:<7} max |Δmean| {:>8.3}% of peak, max |Δstd| {:>8.3}% of peak ({} pts/frame)",
                    mname,
                    100.0 * dev_m,
                    100.0 * dev_s,
                    per_frame
                );
                summary.push((*mname, dev_m));
            }
            summary.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            println!(
                "  QoI-mean fidelity order: {} (paper: GBATC best, SZ worst on minors)",
                summary
                    .iter()
                    .map(|(m, _)| *m)
                    .collect::<Vec<_>>()
                    .join(" < ")
            );
        }
    }
}
