//! Query-cache benchmark: the same query mix against an `ArchiveStore`
//! cold (every plane decoded) and warm (every plane cached), on a
//! multi-shard archive over the pure-Rust reference backend.  Reports
//! latency, the warm/cold speedup, and the warm hit rate, asserts the
//! warm pass decodes zero new sections and returns bit-identical bytes,
//! and writes `BENCH_query.json` (gated against
//! `BENCH_query_baseline.json` by `scripts/bench_compare.py` — the
//! speedup is machine-relative, so the gate is meaningful on any
//! runner):
//!
//! ```bash
//! cargo bench --bench perf_query_cache
//! GBATC_BENCH_PROFILE=small GBATC_BENCH_OUT=out.json cargo bench --bench perf_query_cache
//! ```

use gbatc::api::{Query, SpeciesSel};
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Profile};
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Timer;

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let kt_window: usize = std::env::var("GBATC_KT_WINDOW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let reps: usize = std::env::var("GBATC_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let out_path =
        std::env::var("GBATC_BENCH_OUT").unwrap_or_else(|_| "BENCH_query.json".to_string());

    eprintln!("[bench] generating {profile:?} dataset...");
    let ds = generate(profile, 55);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window,
        ..Default::default()
    };
    let t = Timer::start();
    let report = comp.compress(&ds, &opts).expect("compress");
    let n_shards = report.n_shards;
    let bytes = report.archive.into_bytes();
    eprintln!(
        "[bench] compressed {}x{}x{}x{} into {n_shards} shards ({} B) in {:.1}s",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        bytes.len(),
        t.secs()
    );

    let store = ArchiveStore::with_handle(
        &handle,
        StoreConfig {
            threads: 2,
            cache_bytes: 512 << 20,
            cache_shards: 16,
            ..StoreConfig::default()
        },
    );
    store.mount_bytes("bench", bytes).expect("mount");

    // the repeated-small-query access pattern of post-hoc analysis: per
    // shard window, a single species, a pair, and a cross-shard sweep
    let w = kt_window.min(ds.nt);
    let mut queries: Vec<Query> = Vec::new();
    for t0 in (0..ds.nt).step_by(w) {
        let t1 = (t0 + w).min(ds.nt);
        queries.push(Query {
            time: t0..t1,
            species: SpeciesSel::Indices(vec![ds.ns / 2]),
        });
        queries.push(Query {
            time: t0..t1,
            species: SpeciesSel::Indices(vec![0, ds.ns - 1]),
        });
    }
    queries.push(Query {
        time: 0..ds.nt,
        species: SpeciesSel::Indices(vec![ds.ns / 3]),
    });

    let run_all = |tag: &str| -> (f64, Vec<Vec<f32>>) {
        let t = Timer::start();
        let out: Vec<Vec<f32>> = queries
            .iter()
            .map(|q| store.query("bench", q).expect(tag).mass)
            .collect();
        (t.secs(), out)
    };

    println!(
        "== perf_query_cache ({}x{}x{}x{}, {n_shards} shards, {} queries)",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        queries.len()
    );

    let (cold_s, cold_out) = run_all("cold query");
    let decoded_after_cold = store.stats().decoded_sections;

    let mut warm_s = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let (s, warm_out) = run_all("warm query");
        warm_s = warm_s.min(s);
        // warm responses must be bit-identical to the cold (uncached) pass
        assert_eq!(cold_out.len(), warm_out.len());
        for (a, b) in cold_out.iter().zip(&warm_out) {
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                assert!(x.to_bits() == y.to_bits(), "warm decode diverged");
            }
        }
    }
    let stats = store.stats();
    assert_eq!(
        stats.decoded_sections, decoded_after_cold,
        "warm passes must decode zero new sections"
    );
    let hit_rate = stats.cache.hit_rate();
    let speedup = cold_s / warm_s.max(1e-12);

    println!("cold   {:>9.3} ms  ({} sections decoded)", cold_s * 1e3, decoded_after_cold);
    println!("warm   {:>9.3} ms  (0 sections decoded)", warm_s * 1e3);
    println!(
        "speedup {speedup:.1}x | overall hit rate {:.1}% | cache {}",
        100.0 * hit_rate,
        stats.cache
    );

    // hand-rolled JSON (no serde in the offline image)
    let json = format!(
        "[\n  {{\"kernel\": \"query_cache\", \"cold_ms\": {:.4}, \"warm_ms\": {:.4}, \
         \"speedup\": {:.3}}},\n  {{\"kernel\": \"query_cache_hit_rate\", \
         \"hit_rate\": {:.4}, \"decoded_sections\": {}}}\n]\n",
        cold_s * 1e3,
        warm_s * 1e3,
        speedup,
        hit_rate,
        decoded_after_cold
    );
    std::fs::write(&out_path, &json).expect("write bench json");
    println!("wrote {out_path}");
}
