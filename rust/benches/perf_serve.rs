//! Serving-tier load benchmark: a real [`QueryServer`] on loopback under
//! a skewed hot/cold query mix from many concurrent clients, run twice —
//! keep-alive (one connection per client) vs connection-per-request —
//! and reports throughput, latency percentiles, and the store hit rate.
//! The gated metrics are machine-relative: the keep-alive/close
//! throughput ratio (same machine, same process, same mix), the cache
//! hit rate of the mix, and the tracing-overhead ratio (the same
//! keep-alive phase against a second server with `trace_sample: 0` —
//! default 1-in-16 sampling must cost <= 2% throughput), so the gate in
//! `scripts/bench_compare.py` is meaningful on any runner.  Latency
//! percentiles are reported twice: client-side wall times and the
//! server's own lock-free histogram (`ServeObs::query_latency`), whose
//! p99 lands in `BENCH_serve.json` for trend tracking.  Writes
//! `BENCH_serve.json` (gated against `BENCH_serve_baseline.json`):
//!
//! ```bash
//! cargo bench --bench perf_serve
//! GBATC_BENCH_PROFILE=small GBATC_BENCH_OUT=out.json cargo bench --bench perf_serve
//! ```

use std::sync::Arc;

use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Profile};
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::serve::{QueryClient, QueryServer, ServerConfig};
use gbatc::store::{ArchiveStore, StoreConfig};
use gbatc::util::Timer;

/// One request of the mix: a `/query` window + species list.
#[derive(Clone)]
struct Req {
    t0: usize,
    t1: usize,
    species: String,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let clients: usize = std::env::var("GBATC_SERVE_CLIENTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let reps: usize = std::env::var("GBATC_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let out_path =
        std::env::var("GBATC_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".to_string());

    eprintln!("[bench] generating {profile:?} dataset...");
    let ds = generate(profile, 55);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window: 4,
        ..Default::default()
    };
    let t = Timer::start();
    let report = comp.compress(&ds, &opts).expect("compress");
    let bytes = report.archive.into_bytes();
    eprintln!(
        "[bench] compressed {}x{}x{}x{} ({} B) in {:.1}s",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        bytes.len(),
        t.secs()
    );

    let store = Arc::new(ArchiveStore::with_handle(
        &handle,
        StoreConfig {
            threads: 2,
            cache_bytes: 512 << 20,
            cache_shards: 16,
            ..StoreConfig::default()
        },
    ));
    store.mount_bytes("bench", bytes).expect("mount");
    let server = QueryServer::bind(
        Arc::clone(&store),
        "127.0.0.1:0",
        ServerConfig {
            workers: 4,
            queue: 256,
            max_conns: 4 * clients + 16,
            ..ServerConfig::default()
        },
    )
    .expect("bind");
    let addr = server.addr().to_string();
    eprintln!(
        "[bench] serving on {addr} ({})",
        if server.event_driven() {
            "epoll event loop"
        } else {
            "thread-pool fallback"
        }
    );

    // skewed hot/cold mix: 80% of requests replay one hot window (warm
    // after its first decode), 20% walk cold windows across the axis
    let w = 4usize.min(ds.nt);
    let hot = Req {
        t0: 0,
        t1: w,
        species: format!("{}", ds.ns / 2),
    };
    let mut cold: Vec<Req> = Vec::new();
    for t0 in (0..ds.nt).step_by(w) {
        cold.push(Req {
            t0,
            t1: (t0 + w).min(ds.nt),
            species: format!("0,{}", ds.ns - 1),
        });
    }
    let per_client = (reps.max(1) * 5 * cold.len()).clamp(20, 400);
    let mix: Vec<Req> = (0..per_client)
        .map(|i| {
            if i % 5 == 0 {
                cold[(i / 5) % cold.len()].clone()
            } else {
                hot.clone()
            }
        })
        .collect();

    // warm every distinct window once so both phases measure the same
    // steady-state warm/cold profile
    {
        let c = QueryClient::new(addr.clone());
        c.query("bench", Some(hot.t0), Some(hot.t1), &hot.species)
            .expect("warmup hot");
        for r in &cold {
            c.query("bench", Some(r.t0), Some(r.t1), &r.species)
                .expect("warmup cold");
        }
    }

    // one timed phase: `clients` threads, each running the mix on its
    // own client; returns (requests/sec, sorted per-request latencies)
    let run_phase = |addr: &str, reuse: bool| -> (f64, Vec<f64>) {
        let wall = Timer::start();
        let mut lat: Vec<f64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|_| {
                    let addr = addr.to_string();
                    let mix = &mix;
                    scope.spawn(move || {
                        let client = QueryClient::new(addr).reuse(reuse);
                        let mut lat = Vec::with_capacity(mix.len());
                        for r in mix {
                            let t = Timer::start();
                            let dec = client
                                .query("bench", Some(r.t0), Some(r.t1), &r.species)
                                .expect("bench query");
                            lat.push(t.secs() * 1e3);
                            assert!(!dec.mass.is_empty());
                        }
                        lat
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread"))
                .collect()
        });
        let secs = wall.secs();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ((clients * per_client) as f64 / secs.max(1e-9), lat)
    };

    println!(
        "== perf_serve ({}x{}x{}x{}, {clients} clients x {per_client} reqs, 80/20 hot/cold)",
        ds.nt, ds.ns, ds.ny, ds.nx
    );

    let (close_rps, close_lat) = run_phase(&addr, false);
    let (ka_rps, ka_lat) = run_phase(&addr, true);
    let speedup = ka_rps / close_rps.max(1e-9);

    // the server's own latency view: the lock-free histogram every
    // request lands in, regardless of sampling (ns -> ms for the report)
    let srv_q = server.obs().query_latency();
    let srv_wait = server.obs().queue_wait();
    let ms = |ns: u64| ns as f64 / 1e6;

    let stats = store.stats();
    let hit_rate = stats.cache.hit_rate();
    let st = server.shutdown();
    assert_eq!(st.io_errors, 0, "clean load must not count io errors: {st}");
    assert_eq!(st.server_errors, 0, "{st}");

    // tracing-overhead phase: the identical keep-alive load against a
    // second server (same warm store) with tracing fully disabled.
    // best-of-2 per side to keep the 2% gate out of scheduler noise.
    let overhead_phase = |cfg_sample: u32| -> f64 {
        let s2 = QueryServer::bind(
            Arc::clone(&store),
            "127.0.0.1:0",
            ServerConfig {
                workers: 4,
                queue: 256,
                max_conns: 4 * clients + 16,
                trace_sample: cfg_sample,
                ..ServerConfig::default()
            },
        )
        .expect("bind overhead server");
        let a2 = s2.addr().to_string();
        let rps = run_phase(&a2, true).0.max(run_phase(&a2, true).0);
        let st2 = s2.shutdown();
        assert_eq!(st2.server_errors, 0, "{st2}");
        rps
    };
    let traced_rps = overhead_phase(16); // the default 1-in-16 sampling
    let notrace_rps = overhead_phase(0); // histograms on, spans off
    let trace_overhead = notrace_rps / traced_rps.max(1e-9);

    let report_phase = |tag: &str, rps: f64, lat: &[f64]| {
        println!(
            "{tag:<10} {rps:>9.0} req/s | p50 {:>7.3} ms  p95 {:>7.3} ms  p99 {:>7.3} ms",
            percentile(lat, 0.50),
            percentile(lat, 0.95),
            percentile(lat, 0.99)
        );
    };
    report_phase("close", close_rps, &close_lat);
    report_phase("keep-alive", ka_rps, &ka_lat);
    println!(
        "server hist {:>6} reqs | p50 {:>7.3} ms  p95 {:>7.3} ms  p99 {:>7.3} ms  max {:>7.3} ms | queue-wait p99 {:.3} ms",
        srv_q.count,
        ms(srv_q.p50()),
        ms(srv_q.p95()),
        ms(srv_q.p99()),
        ms(srv_q.max),
        ms(srv_wait.p99()),
    );
    println!(
        "keep-alive/close speedup {speedup:.2}x | hit rate {:.1}% | \
         trace overhead {:.3}x (traced {traced_rps:.0} vs untraced {notrace_rps:.0} req/s) | {st}",
        100.0 * hit_rate,
        trace_overhead,
    );

    // hand-rolled JSON (no serde in the offline image)
    let json = format!(
        "[\n  {{\"kernel\": \"serve_keepalive\", \"close_rps\": {:.1}, \
         \"keepalive_rps\": {:.1}, \"p50_ms\": {:.4}, \"p95_ms\": {:.4}, \
         \"p99_ms\": {:.4}, \"server_p50_ms\": {:.4}, \"server_p99_ms\": {:.4}, \
         \"queue_wait_p99_ms\": {:.4}, \"speedup\": {:.3}}},\n  \
         {{\"kernel\": \"serve_hit_rate\", \"hit_rate\": {:.4}, \
         \"keepalive_reuse\": {}, \"pipelined\": {}}},\n  \
         {{\"kernel\": \"serve_trace_overhead\", \"traced_rps\": {:.1}, \
         \"notrace_rps\": {:.1}, \"ratio\": {:.4}}}\n]\n",
        close_rps,
        ka_rps,
        percentile(&ka_lat, 0.50),
        percentile(&ka_lat, 0.95),
        percentile(&ka_lat, 0.99),
        ms(srv_q.p50()),
        ms(srv_q.p99()),
        ms(srv_wait.p99()),
        speedup,
        hit_rate,
        st.keepalive_reuse,
        st.pipelined,
        traced_rps,
        notrace_rps,
        trace_overhead
    );
    std::fs::write(&out_path, &json).expect("write bench json");
    println!("wrote {out_path}");
}
