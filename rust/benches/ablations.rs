//! Ablations over the design choices DESIGN.md calls out:
//!   A1  TCN on/off (GBATC vs GBA) at fixed target — the paper's own
//!       ablation (Fig. 4's two curves);
//!   A2  latent quantization bin width (paper §II-A bin size d);
//!   A3  Fig.-2 shortest-prefix index encoding vs raw D-bit bitmaps;
//!   A4  truncated vs full stored PCA basis;
//!   A5  model-parameter accounting 8-bit vs f32.

#[path = "common.rs"]
mod common;

use common::*;
use gbatc::codec::CoeffCodec;
use gbatc::compressor::CompressOptions;
use gbatc::util::{BitWriter, Prng};

fn main() {
    let env = BenchEnv::new(77);
    let handle = env.handle();
    let comp = env.compressor(&handle);
    let ds = &env.ds;
    let target = 1e-3;
    println!("== ablations @ target {target:.0e} ({}x{}x{}x{})", ds.nt, ds.ns, ds.ny, ds.nx);

    // A1: TCN on/off ------------------------------------------------------
    println!("\n-- A1: tensor correction network --");
    let mut tcn_archive = None;
    for (name, use_tcn) in [("GBATC (tcn on)", true), ("GBA (tcn off)", false)] {
        let opts = CompressOptions {
            nrmse_target: target,
            use_tcn,
            ..Default::default()
        };
        let report = comp.compress(ds, &opts).unwrap();
        println!(
            "{name:<16} CR {:>7.1} | coeffs {:>9} | {}",
            report.archive.compression_ratio(),
            report.n_coeffs,
            report.breakdown
        );
        if use_tcn {
            tcn_archive = Some(report.archive);
        }
    }

    // A2: latent bin width ---------------------------------------------------
    println!("\n-- A2: latent quantization bin --");
    for bin in [0.005, 0.02, 0.08] {
        let opts = CompressOptions {
            nrmse_target: target,
            latent_bin: bin,
            ..Default::default()
        };
        let report = comp.compress(ds, &opts).unwrap();
        println!(
            "bin {bin:<6} CR {:>7.1} | latents {:>9} B | coeffs {:>9} B",
            report.archive.compression_ratio(),
            report.breakdown.latents,
            report.breakdown.coeffs
        );
    }

    // A3: index encoding (from the real archive's selections) ---------------
    println!("\n-- A3: Fig-2 prefix index encoding vs raw bitmap --");
    let archive = tcn_archive.expect("A1 ran");
    let mut prefix_bits = 0usize;
    let mut raw_bits = 0usize;
    let mut n_sel = 0usize;
    for shard in 0..archive.n_shards() {
        for sec in archive.species_sections(shard).unwrap() {
            let coeffs = CoeffCodec::decode(&sec.coeffs).unwrap();
            for blk in &coeffs.per_block {
                let idxs: Vec<usize> = blk.iter().map(|&(j, _)| j).collect();
                let mut w = BitWriter::new();
                gbatc::codec::encode_indices(&mut w, &idxs, coeffs.d).unwrap();
                prefix_bits += w.bit_len();
                raw_bits += coeffs.d;
                n_sel += idxs.len();
            }
        }
    }
    println!(
        "prefix encoding {:>10} B | raw bitmaps {:>10} B | saving {:.1}x ({} selections)",
        prefix_bits / 8,
        raw_bits / 8,
        raw_bits as f64 / prefix_bits.max(1) as f64,
        n_sel
    );

    // A4: basis truncation ----------------------------------------------------
    println!("\n-- A4: stored basis truncation --");
    for (name, full) in [("truncated", false), ("full DxD", true)] {
        let opts = CompressOptions {
            nrmse_target: target,
            store_full_basis: full,
            ..Default::default()
        };
        let report = comp.compress(ds, &opts).unwrap();
        println!(
            "{name:<10} CR {:>7.1} | bases {:>9} B",
            report.archive.compression_ratio(),
            report.breakdown.bases
        );
    }

    // A5: model byte accounting -------------------------------------------------
    println!("\n-- A5: model parameter accounting --");
    for (name, f32s) in [("8-bit", false), ("f32", true)] {
        let opts = CompressOptions {
            nrmse_target: target,
            model_bytes_f32: f32s,
            ..Default::default()
        };
        let report = comp.compress(ds, &opts).unwrap();
        println!(
            "{name:<6} CR {:>7.1} | model {:>9} B",
            report.archive.compression_ratio(),
            report.breakdown.model_params
        );
    }

    // block-shape sanity: the paper's 4x5x4 vs a degenerate 1x5x4 (no time)
    println!("\n-- A6: spatiotemporal blocking (requires divisible dims) --");
    println!("(block shape is baked into the AOT artifact; see DESIGN.md — the");
    println!(" 4x5x4 block is the paper's choice; retrain aot.py to ablate.)");

    let _ = Prng::new(0); // keep util linked in release-bench builds
}
