//! Streaming-session benchmark: one synthetic field compressed one-shot
//! (`ShardEngine::compress`) and through the push-based
//! `api::CompressSession`, on the pure-Rust reference backend.  Reports
//! wall time and the *peak workspace* of each path, asserts the session
//! stays O(shard) — its peak must not grow with the field while the
//! field/shard ratio does — and writes `BENCH_streaming.json`:
//!
//! ```bash
//! cargo bench --bench perf_streaming
//! GBATC_BENCH_PROFILE=small GBATC_BENCH_OUT=out.json cargo bench --bench perf_streaming
//! ```

use std::io::Cursor;

use gbatc::api::{CompressorBuilder, ErrorPolicy, FieldSpec};
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Dataset, Profile};
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::util::Timer;

struct Row {
    name: &'static str,
    nt: usize,
    field_bytes: usize,
    archive_bytes: usize,
    peak_workspace: usize,
    wall_s: f64,
}

/// Tile a dataset along time to `nt` timesteps (cheaply grows the field
/// so the O(shard)-vs-O(field) gap is visible at bench scale).
fn tile_time(ds: &Dataset, nt: usize) -> Dataset {
    let mut out = Dataset::new(nt, ds.ns, ds.ny, ds.nx);
    let stride = ds.ns * ds.ny * ds.nx;
    for t in 0..nt {
        let src = (t % ds.nt) * stride;
        out.mass[t * stride..(t + 1) * stride].copy_from_slice(&ds.mass[src..src + stride]);
    }
    out.pressure = ds.pressure;
    out
}

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let kt_window: usize = std::env::var("GBATC_KT_WINDOW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let out_path =
        std::env::var("GBATC_BENCH_OUT").unwrap_or_else(|_| "BENCH_streaming.json".to_string());

    eprintln!("[bench] generating {profile:?} dataset...");
    let base = generate(profile, 77);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();

    println!(
        "== perf_streaming ({}x{}x{} grid, kt_window {kt_window})",
        base.ns, base.ny, base.nx
    );
    let mut rows: Vec<Row> = Vec::new();
    // same shard width, growing field: a session's peak workspace must
    // track the shard, not the field
    for nt in [base.nt, base.nt * 2, base.nt * 4] {
        let ds = tile_time(&base, nt);
        let opts = CompressOptions {
            nrmse_target: 1e-3,
            kt_window,
            shard_workers: 1,
            // fixed thread budget keeps the per-shard workspace charge
            // machine-independent, so the O(shard) gate is deterministic
            threads: 2,
            ..Default::default()
        };

        let comp = GbatcCompressor::new(&handle, 0, 0);
        let t = Timer::start();
        let report = comp.compress(&ds, &opts).expect("one-shot compress");
        rows.push(Row {
            name: "one_shot",
            nt,
            field_bytes: ds.pd_bytes(),
            archive_bytes: report.archive.payload_bytes(),
            peak_workspace: report.peak_workspace_bytes,
            wall_s: t.secs(),
        });

        let builder = CompressorBuilder::from_options(&opts).error_policy(ErrorPolicy::Uniform(1e-3));
        let t = Timer::start();
        let mut session = builder
            .session_on(
                &handle,
                0,
                0,
                FieldSpec::from_dataset(&ds),
                Cursor::new(Vec::new()),
            )
            .expect("open session");
        session.push_dataset(&ds).expect("push");
        let (sreport, sink) = session.finish_into().expect("finish");
        let streamed = sink.into_inner();
        assert_eq!(
            streamed, report.archive.bytes,
            "streamed archive must be byte-identical to one-shot"
        );
        rows.push(Row {
            name: "session",
            nt,
            field_bytes: ds.pd_bytes(),
            archive_bytes: streamed.len(),
            peak_workspace: sreport.peak_workspace_bytes,
            wall_s: t.secs(),
        });
    }

    for r in &rows {
        println!(
            "{:>8}  nt {:>4}  field {:>12} B  archive {:>10} B  peak workspace {:>11} B  {:>6.2}s",
            r.name, r.nt, r.field_bytes, r.archive_bytes, r.peak_workspace, r.wall_s
        );
    }

    // hand-rolled JSON (no serde in the offline image)
    let mut json = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "  {{\"name\": \"{}\", \"nt\": {}, \"field_bytes\": {}, \"archive_bytes\": {}, \
             \"peak_workspace_bytes\": {}, \"wall_time_s\": {:.4}}}{}\n",
            r.name,
            r.nt,
            r.field_bytes,
            r.archive_bytes,
            r.peak_workspace,
            r.wall_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("]\n");
    std::fs::write(&out_path, &json).expect("write bench json");
    println!("wrote {out_path}");

    // the gate: session peak workspace is O(shard) — quadrupling the
    // field must not move it by more than the fp/accounting noise floor
    let peaks: Vec<usize> = rows
        .iter()
        .filter(|r| r.name == "session")
        .map(|r| r.peak_workspace)
        .collect();
    let (first, last) = (peaks[0], peaks[peaks.len() - 1]);
    assert!(
        last <= first + first / 10,
        "session peak workspace grew with the field: {first} B -> {last} B (not O(shard))"
    );
    // and it must stay well under the field itself once the field dwarfs
    // one shard
    let big = rows.last().unwrap();
    assert!(
        last < big.field_bytes,
        "session peak workspace {last} B >= field {} B",
        big.field_bytes
    );
    println!(
        "session peak workspace stable at {first} B across a {}x field growth (field {} B)",
        rows.last().unwrap().nt / rows[0].nt,
        big.field_bytes
    );
}
