//! Figure 4 reproduction: PD error vs compression ratio (a) and QoI error
//! vs compression ratio (b) for GBATC, GBA, and SZ.
//!
//! Paper reference (S3D HCCI, 640x640x50x58): at PD NRMSE 1e-3 the paper
//! reports CR ≈ 600 (GBATC), 400 (GBA), 150 (SZ); GBATC < GBA < SZ in PD
//! error at fixed CR, and QoI errors ordered the same way.
//!
//! ```bash
//! GBATC_BENCH_PROFILE=medium cargo bench --bench fig4_tradeoff
//! ```

#[path = "common.rs"]
mod common;

use common::*;
use gbatc::util::Timer;

fn main() {
    let env = BenchEnv::new(1234);
    let handle = env.handle();
    let stride = 4;
    println!(
        "== Fig 4: error vs compression ratio ({}x{}x{}x{}, {:.0} MB PD)",
        env.ds.nt,
        env.ds.ns,
        env.ds.ny,
        env.ds.nx,
        env.ds.pd_bytes() as f64 / 1e6
    );

    let mut rows = Vec::new();
    for &target in &[3e-2, 1e-2, 3e-3, 1e-3] {
        for (method, use_tcn) in [("GBATC", true), ("GBA", false)] {
            let t = Timer::start();
            let (cr, recon) = run_gbatc(&env, &handle, target, use_tcn);
            let (_, pd) = species_nrmse(&env.ds, &recon);
            let (_, qoi) = qoi_nrmse(&env.ds, &recon, stride);
            eprintln!(
                "[bench] {method} @ {target:.0e}: CR {cr:.1} pd {pd:.2e} qoi {qoi:.2e} ({:.1}s)",
                t.secs()
            );
            rows.push(Row {
                method,
                target,
                cr,
                pd,
                qoi,
            });
        }
        let t = Timer::start();
        let (cr, recon) = run_sz(&env, target, 1.0);
        let (_, pd) = species_nrmse(&env.ds, &recon);
        let (_, qoi) = qoi_nrmse(&env.ds, &recon, stride);
        eprintln!(
            "[bench] SZ    @ {target:.0e}: CR {cr:.1} pd {pd:.2e} qoi {qoi:.2e} ({:.1}s)",
            t.secs()
        );
        rows.push(Row {
            method: "SZ",
            target,
            cr,
            pd,
            qoi,
        });
    }

    println!("\n-- Fig 4a (PD) & 4b (QoI) rows --");
    print_rows(&rows);

    // headline check: at the 1e-3 working point, GBATC >= GBA > SZ in CR
    let cr_of = |m: &str| {
        rows.iter()
            .find(|r| r.method == m && (r.target - 1e-3).abs() < 1e-12)
            .map(|r| r.cr)
            .unwrap()
    };
    println!("\n-- headline @ NRMSE 1e-3 --");
    println!(
        "GBATC CR {:.1} | GBA CR {:.1} | SZ CR {:.1}   (paper: 600 / 400 / 150)",
        cr_of("GBATC"),
        cr_of("GBA"),
        cr_of("SZ")
    );
    let ok = cr_of("GBATC") >= cr_of("GBA") && cr_of("GBA") > cr_of("SZ");
    println!(
        "shape {}: GBATC >= GBA > SZ",
        if ok { "HOLDS" } else { "VIOLATED" }
    );
}
