//! Hot-path microbenchmarks — the §Perf harness (EXPERIMENTS.md).
//!
//! Covers every stage of the request path: PJRT executions (encoder /
//! decoder / TCN), Huffman coding, PCA fit + guarantee loop, SZ predictors,
//! block gather/scatter, and the end-to-end compress/decompress throughput.

#[path = "common.rs"]
mod common;

use common::*;
use gbatc::compressor::{CompressOptions, SzCompressOptions, SzCompressor};
use gbatc::data::blocks::{BlockGrid, BlockShape};
use gbatc::entropy::IntCodec;
use gbatc::gae::guarantee::{guarantee_species, GuaranteeParams};
use gbatc::sz::codec::{sz_compress, SzMode};
use gbatc::util::timer::bench;
use gbatc::util::Prng;

fn main() {
    let env = BenchEnv::new(99);
    let handle = env.handle();
    let ds = &env.ds;
    let spec = handle.spec();
    println!("== perf_hotpaths ({}x{}x{}x{})", ds.nt, ds.ns, ds.ny, ds.nx);

    // --- PJRT executions ------------------------------------------------
    let il = spec.instance_len();
    let blocks = vec![0.1f32; spec.batch * il];
    let st = bench(1, 5, || {
        let _ = handle.encode(blocks.clone(), spec.batch).unwrap();
    });
    println!(
        "encoder exec    [{} blocks]  {st}  ({:.1} blocks/s)",
        spec.batch,
        st.throughput(spec.batch as f64)
    );
    let latents = vec![0.1f32; spec.batch * spec.latent];
    let st = bench(1, 5, || {
        let _ = handle.decode(latents.clone(), spec.batch).unwrap();
    });
    println!(
        "decoder exec    [{} blocks]  {st}  ({:.1} blocks/s)",
        spec.batch,
        st.throughput(spec.batch as f64)
    );
    let pts = vec![0.1f32; spec.points * spec.species];
    let st = bench(1, 5, || {
        let _ = handle.tcn(pts.clone(), spec.points).unwrap();
    });
    let tcn_flops = 2.0
        * spec.points as f64
        * (58.0 * 232.0 + 232.0 * 464.0 + 464.0 * 232.0 + 232.0 * 58.0);
    println!(
        "tcn exec        [{} pts]    {st}  ({:.2} GFLOP/s)",
        spec.points,
        tcn_flops / st.mean_s / 1e9
    );

    // --- entropy coding ---------------------------------------------------
    let mut rng = Prng::new(1);
    let syms: Vec<i64> = (0..1_000_000)
        .map(|_| (rng.normal() * 3.0) as i64)
        .collect();
    let st = bench(1, 5, || {
        let _ = IntCodec::encode(&syms).unwrap();
    });
    println!(
        "huffman encode  [1M syms]    {st}  ({:.1} Msym/s)",
        1.0 / st.mean_s
    );
    let enc = IntCodec::encode(&syms).unwrap();
    let st = bench(1, 5, || {
        let _ = IntCodec::decode(&enc).unwrap();
    });
    println!(
        "huffman decode  [1M syms]    {st}  ({:.1} Msym/s)",
        1.0 / st.mean_s
    );

    // --- PCA + guarantee --------------------------------------------------
    let grid = BlockGrid::for_dataset(ds, BlockShape::default()).unwrap();
    let n_blocks = grid.n_blocks();
    let d = grid.shape.d();
    let mut orig_s = vec![0.0f32; n_blocks * d];
    let mut recon_s = vec![0.0f32; n_blocks * d];
    for b in 0..n_blocks {
        grid.gather_species(&ds.mass, b, 5, &mut orig_s[b * d..(b + 1) * d]);
    }
    let mut rng = Prng::new(2);
    for (r, o) in recon_s.iter_mut().zip(&orig_s) {
        *r = o + 1e-4 * rng.normal() as f32;
    }
    let params = GuaranteeParams::for_tau(1e-3 * (d as f64).sqrt(), d);
    let st = bench(1, 3, || {
        let _ = guarantee_species(&orig_s, &recon_s, n_blocks, d, &params);
    });
    println!(
        "guarantee pass  [{} blocks, 1 species]  {st}  ({:.0} blocks/s)",
        n_blocks,
        st.throughput(n_blocks as f64)
    );

    // --- block gather/scatter ----------------------------------------------
    let mut inst = vec![0.0f32; grid.instance_len()];
    let st = bench(1, 5, || {
        for b in 0..n_blocks {
            grid.gather(&ds.mass, b, &mut inst);
        }
    });
    println!(
        "block gather    [{} blocks]  {st}  ({:.1} GB/s)",
        n_blocks,
        (n_blocks * grid.instance_len() * 4) as f64 / st.mean_s / 1e9
    );

    // --- SZ predictors ------------------------------------------------------
    let field = ds.species_field(5);
    for mode in [SzMode::Lorenzo, SzMode::Interp] {
        let st = bench(1, 3, || {
            let _ = sz_compress(&field.data, (ds.nt, ds.ny, ds.nx), 1e-5, mode).unwrap();
        });
        println!(
            "sz {:<12} [1 species]  {st}  ({:.1} MB/s)",
            format!("{mode:?}"),
            (field.data.len() * 4) as f64 / st.mean_s / 1e6
        );
    }

    // --- end-to-end ----------------------------------------------------------
    let comp = env.compressor(&handle);
    let opts = CompressOptions {
        nrmse_target: 1e-3,
        ..Default::default()
    };
    let st = bench(0, 2, || {
        let _ = comp.compress(ds, &opts).unwrap();
    });
    println!(
        "GBATC compress  [end-to-end]  {st}  ({:.1} MB/s)",
        ds.pd_bytes() as f64 / st.mean_s / 1e6
    );
    let report = comp.compress(ds, &opts).unwrap();
    let st = bench(0, 2, || {
        let _ = comp.decompress(&report.archive, 0).unwrap();
    });
    println!(
        "GBATC decompress[end-to-end]  {st}  ({:.1} MB/s)",
        ds.pd_bytes() as f64 / st.mean_s / 1e6
    );
    let szc = SzCompressor::new(SzCompressOptions::default());
    let st = bench(0, 2, || {
        let _ = szc.compress(ds, 1e-3).unwrap();
    });
    println!(
        "SZ compress     [end-to-end]  {st}  ({:.1} MB/s)",
        ds.pd_bytes() as f64 / st.mean_s / 1e6
    );
}
