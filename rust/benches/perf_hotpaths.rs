//! Hot-path kernel benchmark — per-kernel before/after numbers for the
//! overhauled paths (guarantee/PCA, table-driven Huffman, planner trial
//! reuse, the SIMD-dispatched NRMSE sweep, the Lorenzo interior fast
//! path, and the slice-by-8 CRC-32 behind the streaming journal), on the
//! pure-Rust reference backend so CI can run it without AOT artifacts:
//!
//! ```bash
//! cargo bench --bench perf_hotpaths
//! GBATC_BENCH_PROFILE=tiny GBATC_BENCH_OUT=BENCH_hotpaths.json \
//!     cargo bench --bench perf_hotpaths
//! ```
//!
//! Each "baseline" is a faithful copy of the pre-overhaul kernel (scalar
//! per-column dots + separate re-measure, bit-position reader + canonical
//! walk, per-bit symbol writes), and every (baseline, optimized) pair is
//! asserted to produce identical results before it is timed — the
//! overhaul's bit-identity contract, enforced where the numbers are
//! produced.  Results land in `BENCH_hotpaths.json`; CI gates regressions
//! with `scripts/bench_compare.py` against the committed baseline.
//! `GBATC_BENCH_STRICT=1` additionally asserts the headline targets
//! (guarantee >= 2x, Huffman decode >= 3x, auto within 1.2x of the best
//! single-codec run) in-process.

use gbatc::compressor::{CodecChoice, CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Profile};
use gbatc::entropy::Huffman;
use gbatc::gae::guarantee::{guarantee_species_timed, GuaranteeParams};
use gbatc::gae::SpeciesBasis;
use gbatc::linalg::Pca;
use gbatc::quant::UniformQuantizer;
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::sz::lorenzo::Lorenzo3;
use gbatc::sz::ErrorBoundQuantizer;
use gbatc::util::timer::bench;
use gbatc::util::{BitReader, BitWriter, Prng, Timer};

/// Faithful copies of the pre-overhaul kernels, used as the "before"
/// side of every measurement (the originals no longer exist in-tree).
mod baseline {
    use super::*;

    /// Pre-overhaul Algorithm 1: per-block scalar column dots, separate
    /// axpy + re-measure sweeps, eager corrected clone, and the second
    /// `from_mat` conversion for the truncated basis.
    #[allow(clippy::type_complexity)]
    pub fn guarantee_species(
        orig: &[f32],
        recon: &[f32],
        n_blocks: usize,
        d: usize,
        params: &GuaranteeParams,
    ) -> (Vec<Vec<(usize, i64)>>, f64, usize) {
        let tau = params.tau;
        let bin = params.coeff_bin.min(1.9 * tau / (d as f64).sqrt());
        let quant = UniformQuantizer::new(bin);
        let mut residuals = vec![0.0f32; n_blocks * d];
        for i in 0..n_blocks * d {
            residuals[i] = orig[i] - recon[i];
        }
        let pca = Pca::fit(&residuals, n_blocks, d, false);
        let full_basis = SpeciesBasis::from_mat(&pca.basis, d);

        let mut per_block: Vec<Vec<(usize, i64)>> = Vec::with_capacity(n_blocks);
        let mut corrected = recon.to_vec();
        let mut n_coeffs = 0usize;
        let mut max_residual = 0.0f64;
        let mut max_index_used = 0usize;
        let mut resid = vec![0.0f32; d];
        let mut coeffs: Vec<(usize, f64)> = Vec::with_capacity(d);

        for b in 0..n_blocks {
            let r0 = &residuals[b * d..(b + 1) * d];
            let mut delta2: f64 = r0.iter().map(|&v| (v as f64) * (v as f64)).sum();
            let mut selected: Vec<(usize, i64)> = Vec::new();
            if delta2.sqrt() > tau {
                resid.copy_from_slice(r0);
                coeffs.clear();
                for j in 0..d {
                    let col = full_basis.col(j);
                    let c: f64 = col
                        .iter()
                        .zip(r0)
                        .map(|(&u, &r)| u as f64 * r as f64)
                        .sum();
                    coeffs.push((j, c));
                }
                coeffs.sort_by(|a, b| (b.1 * b.1).total_cmp(&(a.1 * a.1)));
                for &(j, c) in coeffs.iter() {
                    let q = quant.quantize(c);
                    if q == 0 {
                        continue;
                    }
                    let cq = quant.dequantize(q) as f32;
                    full_basis.axpy_col(j, -cq, &mut resid);
                    delta2 = resid.iter().map(|&v| (v as f64) * (v as f64)).sum();
                    selected.push((j, q));
                    if delta2.sqrt() <= tau {
                        break;
                    }
                }
                selected.sort_unstable_by_key(|&(j, _)| j);
                let cb = &mut corrected[b * d..(b + 1) * d];
                for i in 0..d {
                    cb[i] = orig[b * d + i] - resid[i];
                }
                if let Some(&(j, _)) = selected.iter().max_by_key(|&&(j, _)| j) {
                    max_index_used = max_index_used.max(j + 1);
                }
            }
            n_coeffs += selected.len();
            max_residual = max_residual.max(delta2.sqrt());
            per_block.push(selected);
        }
        // the old path converted the Mat a second time for the truncation
        let rank = if params.store_full_basis {
            d
        } else {
            max_index_used
        };
        let basis = SpeciesBasis::from_mat(&pca.basis, rank);
        std::hint::black_box(&basis);
        std::hint::black_box(&corrected);
        (per_block, max_residual, n_coeffs)
    }

    /// Pre-overhaul bit reader: byte-index/bit-offset arithmetic per read.
    pub struct OldBitReader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> OldBitReader<'a> {
        pub fn new(buf: &'a [u8]) -> Self {
            Self { buf, pos: 0 }
        }

        #[inline]
        pub fn read(&mut self, n: u32) -> Option<u64> {
            if self.pos + n as usize > self.buf.len() * 8 {
                return None;
            }
            let mut v = 0u64;
            let mut got = 0u32;
            while got < n {
                let byte = self.buf[(self.pos + got as usize) / 8];
                let bit_off = ((self.pos + got as usize) % 8) as u32;
                let take = (8 - bit_off).min(n - got);
                let bits = ((byte >> bit_off) as u64) & ((1u64 << take) - 1);
                v |= bits << got;
                got += take;
            }
            self.pos += n as usize;
            Some(v)
        }

        #[inline]
        pub fn read_bit(&mut self) -> Option<bool> {
            self.read(1).map(|b| b != 0)
        }
    }

    /// Canonical decode tables rebuilt from public code lengths (the
    /// pre-table decoder's private state).
    pub struct CanonicalWalk {
        count: Vec<u64>,
        first_code: Vec<u64>,
        first_index: Vec<usize>,
        sorted: Vec<u32>,
        max_len: u32,
    }

    pub fn canonical_walk_tables(lens: &[u32]) -> CanonicalWalk {
        let max_len = lens.iter().cloned().max().unwrap_or(0);
        let mut sorted: Vec<u32> = (0..lens.len() as u32)
            .filter(|&s| lens[s as usize] > 0)
            .collect();
        sorted.sort_by_key(|&s| (lens[s as usize], s));
        let mut count = vec![0u64; (max_len + 1) as usize];
        for &s in &sorted {
            count[lens[s as usize] as usize] += 1;
        }
        let mut first_code = vec![0u64; (max_len + 1) as usize];
        let mut first_index = vec![0usize; (max_len + 1) as usize];
        let (mut code, mut idx) = (0u64, 0usize);
        for l in 1..=max_len as usize {
            first_code[l] = code;
            first_index[l] = idx;
            code = (code + count[l]) << 1;
            idx += count[l] as usize;
        }
        CanonicalWalk {
            count,
            first_code,
            first_index,
            sorted,
            max_len,
        }
    }

    /// Pre-overhaul `decode_symbol`: one reader call per bit.
    #[inline]
    pub fn decode_symbol(t: &CanonicalWalk, r: &mut OldBitReader) -> Option<u32> {
        let mut code = 0u64;
        let mut l = 0usize;
        loop {
            let bit = r.read_bit()?;
            code = (code << 1) | bit as u64;
            l += 1;
            if l > t.max_len as usize {
                return None;
            }
            let c = t.count[l];
            if c > 0 {
                let fc = t.first_code[l];
                if code >= fc && code < fc + c {
                    return Some(t.sorted[t.first_index[l] + (code - fc) as usize]);
                }
            }
        }
    }

    /// Pre-overhaul `encode_symbol`: one writer call per bit, MSB-first.
    #[inline]
    pub fn encode_symbol(w: &mut BitWriter, code: u64, len: u32) {
        for i in (0..len).rev() {
            w.write_bit((code >> i) & 1 == 1);
        }
    }

    /// Pre-SIMD NRMSE: one sequential squared-error chain plus a
    /// sequential min/max sweep (the scalar loops `gbatc::simd`'s
    /// fixed-lane kernels replaced).
    pub fn nrmse(orig: &[f32], recon: &[f32]) -> f64 {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &v in orig {
            if v < lo {
                lo = v;
            }
            if v > hi {
                hi = v;
            }
        }
        let mse: f64 = orig
            .iter()
            .zip(recon)
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum::<f64>()
            / orig.len() as f64;
        let range = (hi - lo) as f64;
        if range <= 0.0 {
            return if mse == 0.0 { 0.0 } else { f64::INFINITY };
        }
        mse.sqrt() / range
    }

    /// Pre-fast-path Lorenzo pass: the all-branches predictor at every
    /// cell (the interior fast path's oracle), same raster walk.
    pub fn lorenzo_compress(
        nt: usize,
        ny: usize,
        nx: usize,
        data: &mut [f32],
        q: &ErrorBoundQuantizer,
        syms: &mut Vec<gbatc::sz::quantizer::Sym>,
    ) {
        let at = |r: &[f32], tt: usize, yy: usize, xx: usize| -> f64 {
            r[(tt * ny + yy) * nx + xx] as f64
        };
        for t in 0..nt {
            for y in 0..ny {
                for x in 0..nx {
                    let mut p = 0.0f64;
                    if x > 0 {
                        p += at(data, t, y, x - 1);
                    }
                    if y > 0 {
                        p += at(data, t, y - 1, x);
                    }
                    if t > 0 {
                        p += at(data, t - 1, y, x);
                    }
                    if x > 0 && y > 0 {
                        p -= at(data, t, y - 1, x - 1);
                    }
                    if x > 0 && t > 0 {
                        p -= at(data, t - 1, y, x - 1);
                    }
                    if y > 0 && t > 0 {
                        p -= at(data, t - 1, y - 1, x);
                    }
                    if x > 0 && y > 0 && t > 0 {
                        p += at(data, t - 1, y - 1, x - 1);
                    }
                    let i = (t * ny + y) * nx + x;
                    let (sym, recon) = q.quantize(data[i] as f64, p);
                    syms.push(sym);
                    data[i] = recon as f32;
                }
            }
        }
    }
}

struct SpeedupRow {
    kernel: &'static str,
    baseline_ms: f64,
    optimized_ms: f64,
}

impl SpeedupRow {
    fn speedup(&self) -> f64 {
        self.baseline_ms / self.optimized_ms.max(1e-9)
    }
}

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let reps: usize = std::env::var("GBATC_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let out_path =
        std::env::var("GBATC_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpaths.json".to_string());
    let strict = std::env::var("GBATC_BENCH_STRICT").is_ok_and(|v| v == "1");
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4);
    let mut rows: Vec<SpeedupRow> = Vec::new();

    println!("== perf_hotpaths (kernel before/after, {threads} cores)");

    // --- guarantee / PCA kernel -------------------------------------------
    // synthetic residuals with low-dim structure (like AE errors); sized so
    // nearly every block is above tau and the projection dominates (the
    // shared Jacobi eigensolve is O(d^3), so enough blocks are needed for
    // the per-block work to be the signal)
    let (n_blocks, d) = (2048usize, 80usize);
    let mut rng = Prng::new(1);
    let dirs: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
        .collect();
    let orig: Vec<f32> = (0..n_blocks * d).map(|_| rng.normal() as f32).collect();
    let mut recon = orig.clone();
    for b in 0..n_blocks {
        for dir in &dirs {
            let c = rng.normal() as f32 * 0.3;
            for i in 0..d {
                recon[b * d + i] += c * dir[i];
            }
        }
    }
    let params = GuaranteeParams::for_tau(0.05, d);

    // bit-identity contract first, then the clocks
    let (new_res, _) = guarantee_species_timed(&orig, &recon, n_blocks, d, &params, threads);
    let (old_blocks, old_maxres, old_ncoeffs) =
        baseline::guarantee_species(&orig, &recon, n_blocks, d, &params);
    assert_eq!(new_res.per_block, old_blocks, "guarantee kernels diverged");
    assert_eq!(new_res.max_residual.to_bits(), old_maxres.to_bits());
    assert_eq!(new_res.n_coeffs, old_ncoeffs);

    let st_old = bench(1, reps, || {
        let _ = baseline::guarantee_species(&orig, &recon, n_blocks, d, &params);
    });
    let st_new = bench(1, reps, || {
        let _ = guarantee_species_timed(&orig, &recon, n_blocks, d, &params, threads);
    });
    println!(
        "guarantee pass  [{n_blocks} blocks x {d}]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "guarantee",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- PCA covariance fit (stripe-parallel, bit-identical) ---------------
    let residuals: Vec<f32> = orig.iter().zip(&recon).map(|(a, b)| a - b).collect();
    let seq = Pca::fit_threads(&residuals, n_blocks, d, false, 1);
    let par = Pca::fit_threads(&residuals, n_blocks, d, false, threads);
    assert_eq!(seq.basis.data, par.basis.data, "parallel PCA diverged");
    let st_old = bench(1, reps, || {
        let _ = Pca::fit_threads(&residuals, n_blocks, d, false, 1);
    });
    let st_new = bench(1, reps, || {
        let _ = Pca::fit_threads(&residuals, n_blocks, d, false, threads);
    });
    println!(
        "pca fit         [{n_blocks} x {d}]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "pca_fit",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- Huffman decode / encode ------------------------------------------
    let mut rng = Prng::new(2);
    let n_syms = 1_000_000usize;
    let symbols: Vec<u32> = (0..n_syms)
        .map(|_| ((rng.normal() * 3.0).round().abs() as u32).min(31))
        .collect();
    let mut counts = vec![0u64; 32];
    for &s in &symbols {
        counts[s as usize] += 1;
    }
    let huff = Huffman::from_counts(&counts).expect("huffman");
    let mut w = BitWriter::new();
    for &s in &symbols {
        huff.encode_symbol(&mut w, s);
    }
    let bytes = w.finish();
    let walk = baseline::canonical_walk_tables(&huff.lens);

    // equality contract: old and new decoders agree symbol for symbol
    {
        let mut fast = BitReader::new(&bytes);
        let mut slow = baseline::OldBitReader::new(&bytes);
        for (i, &want) in symbols.iter().enumerate() {
            let a = huff.decode_symbol(&mut fast).expect("decode");
            let b = baseline::decode_symbol(&walk, &mut slow).expect("decode");
            assert_eq!(a, b, "symbol {i}");
            assert_eq!(a, want, "symbol {i}");
        }
    }
    let st_old = bench(1, reps, || {
        let mut r = baseline::OldBitReader::new(&bytes);
        let mut acc = 0u64;
        for _ in 0..n_syms {
            acc = acc.wrapping_add(baseline::decode_symbol(&walk, &mut r).unwrap() as u64);
        }
        std::hint::black_box(acc);
    });
    let st_new = bench(1, reps, || {
        let mut r = BitReader::new(&bytes);
        let mut acc = 0u64;
        for _ in 0..n_syms {
            acc = acc.wrapping_add(huff.decode_symbol(&mut r).unwrap() as u64);
        }
        std::hint::black_box(acc);
    });
    println!(
        "huffman decode  [1M syms]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "huffman_decode",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // byte-identity of the accumulator encoder, then the clocks
    {
        let mut slow = BitWriter::new();
        for &s in &symbols[..10_000] {
            baseline::encode_symbol(&mut slow, huff.codes[s as usize], huff.lens[s as usize]);
        }
        let mut fast = BitWriter::new();
        for &s in &symbols[..10_000] {
            huff.encode_symbol(&mut fast, s);
        }
        assert_eq!(slow.finish(), fast.finish(), "encoders diverged");
    }
    let st_old = bench(1, reps, || {
        let mut w = BitWriter::new();
        for &s in &symbols {
            baseline::encode_symbol(&mut w, huff.codes[s as usize], huff.lens[s as usize]);
        }
        std::hint::black_box(w.finish());
    });
    let st_new = bench(1, reps, || {
        let mut w = BitWriter::new();
        for &s in &symbols {
            huff.encode_symbol(&mut w, s);
        }
        std::hint::black_box(w.finish());
    });
    println!(
        "huffman encode  [1M syms]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "huffman_encode",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- NRMSE sweep (fixed-lane SIMD kernels) ----------------------------
    let mut rng = Prng::new(3);
    let n_pts = 4_000_000usize;
    let a: Vec<f32> = (0..n_pts).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = a
        .iter()
        .map(|&v| v + rng.normal() as f32 * 1e-3)
        .collect();
    // the lane reduction redefines the canonical sum order, so old and
    // new agree to rounding (dispatch == scalar-oracle bit-identity is
    // asserted where it holds: src/simd property tests)
    let (old_v, new_v) = (baseline::nrmse(&a, &b), gbatc::metrics::nrmse(&a, &b));
    assert!(
        (old_v - new_v).abs() <= 1e-12 * old_v.abs().max(1e-30),
        "nrmse kernels diverged: {old_v} vs {new_v}"
    );
    let st_old = bench(1, reps, || {
        std::hint::black_box(baseline::nrmse(&a, &b));
    });
    let st_new = bench(1, reps, || {
        std::hint::black_box(gbatc::metrics::nrmse(&a, &b));
    });
    println!(
        "nrmse sweep     [{}M pts]  before {}  after {}  ({:.2}x)",
        n_pts / 1_000_000,
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "nrmse_sweep",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- Lorenzo predictor (interior fast path) ---------------------------
    let (lnt, lny, lnx) = (16usize, 96usize, 96usize);
    let mut rng = Prng::new(4);
    let field: Vec<f32> = (0..lnt * lny * lnx)
        .map(|i| {
            let t = i / (lny * lnx);
            ((t as f32) * 0.3).sin() + ((i % lnx) as f32 * 0.15).cos() + rng.normal() as f32 * 0.01
        })
        .collect();
    let q = ErrorBoundQuantizer::new(1e-4);
    let lz = Lorenzo3::new(lnt, lny, lnx);
    // bit-identity contract: same symbols, same reconstructions
    {
        let mut old_work = field.clone();
        let mut old_syms = Vec::new();
        baseline::lorenzo_compress(lnt, lny, lnx, &mut old_work, &q, &mut old_syms);
        let mut new_work = field.clone();
        let mut new_syms = Vec::new();
        lz.compress(&mut new_work, &q, &mut new_syms);
        assert_eq!(old_syms, new_syms, "lorenzo symbol streams diverged");
        assert_eq!(old_work, new_work, "lorenzo reconstructions diverged");
    }
    let st_old = bench(1, reps, || {
        let mut work = field.clone();
        let mut syms = Vec::new();
        baseline::lorenzo_compress(lnt, lny, lnx, &mut work, &q, &mut syms);
        std::hint::black_box(syms.len());
    });
    let st_new = bench(1, reps, || {
        let mut work = field.clone();
        let mut syms = Vec::new();
        lz.compress(&mut work, &q, &mut syms);
        std::hint::black_box(syms.len());
    });
    println!(
        "lorenzo predict [{lnt}x{lny}x{lnx}]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "lorenzo_predict",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- CRC-32 sweep (slice-by-8 vs the bytewise oracle) ------------------
    // the durability tax: every shard payload and journal record is
    // CRC-framed, so checksum throughput sits on the ingest hot path
    let mut rng = Prng::new(5);
    let blob: Vec<u8> = (0..8usize << 20).map(|_| rng.next_u64() as u8).collect();
    // digest-identity contract first, then the clocks
    assert_eq!(
        gbatc::util::crc32::crc32(&blob),
        gbatc::util::crc32::crc32_bytewise(&blob),
        "crc32 kernels diverged"
    );
    let st_old = bench(1, reps, || {
        std::hint::black_box(gbatc::util::crc32::crc32_bytewise(&blob));
    });
    let st_new = bench(1, reps, || {
        std::hint::black_box(gbatc::util::crc32::crc32(&blob));
    });
    println!(
        "crc32 sweep     [8 MiB]  before {}  after {}  ({:.2}x)",
        st_old, st_new,
        st_old.mean_s / st_new.mean_s
    );
    rows.push(SpeedupRow {
        kernel: "crc32_sweep",
        baseline_ms: st_old.mean_s * 1e3,
        optimized_ms: st_new.mean_s * 1e3,
    });

    // --- planner: auto vs single-codec wall time ---------------------------
    eprintln!("[bench] generating {profile:?} dataset...");
    let ds = generate(profile, 42);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);
    let mut singles: Vec<(&'static str, usize, f64)> = Vec::new();
    let mut auto_s = 0.0f64;
    let mut auto_stages = String::new();
    let mut stage_json = String::new();
    for (name, codec) in [
        ("gbatc", CodecChoice::Gbatc),
        ("sz", CodecChoice::Sz),
        ("dense", CodecChoice::Dense),
        ("auto", CodecChoice::Auto),
    ] {
        let opts = CompressOptions {
            nrmse_target: 1e-3,
            kt_window: 4,
            codec,
            ..Default::default()
        };
        let t = Timer::start();
        let report = comp.compress(&ds, &opts).expect("compress");
        let wall = t.secs();
        println!(
            "compress {name:>6}  {:>10} B  {wall:>7.2}s  [{}]",
            report.archive.total_bytes(),
            report.stage_times
        );
        if name == "auto" {
            auto_s = wall;
            auto_stages = report.stage_times.to_string();
            let st = report.stage_times;
            stage_json = format!(
                "{{\"kernel\": \"stage_times\", \"pca_fit_s\": {:.4}, \"guarantee_s\": {:.4}, \
                 \"entropy_s\": {:.4}, \"planner_trials_s\": {:.4}, \
                 \"pca_fit_p99_ms\": {:.3}, \"guarantee_p99_ms\": {:.3}, \
                 \"entropy_p99_ms\": {:.3}, \"planner_trials_p99_ms\": {:.3}, \
                 \"pca_fit_n\": {}, \"guarantee_n\": {}}}",
                st.pca_fit.total_s,
                st.guarantee.total_s,
                st.entropy.total_s,
                st.planner_trials.total_s,
                st.pca_fit.p99_ms,
                st.guarantee.p99_ms,
                st.entropy.p99_ms,
                st.planner_trials.p99_ms,
                st.pca_fit.count,
                st.guarantee.count
            );
        } else {
            singles.push((name, report.archive.total_bytes(), wall));
        }
    }
    // "best single codec" = the one you would otherwise run: smallest bytes
    let &(best_name, _, best_s) = singles
        .iter()
        .min_by_key(|&&(_, bytes, _)| bytes)
        .expect("singles");
    let ratio = auto_s / best_s.max(1e-9);
    // "trials and nothing more": auto runs the union of the single-codec
    // stages once (one normalize, one model pass, zero-recompute trials,
    // memoized bytes) — so it must not exceed the three single runs
    // combined.  This is the machine-robust gate; the 1.2x-of-best figure
    // is recorded and strict-asserted.
    let sum_s: f64 = singles.iter().map(|&(_, _, s)| s).sum();
    let ratio_vs_sum = auto_s / sum_s.max(1e-9);
    println!(
        "planner: auto {auto_s:.2}s vs best single ({best_name}) {best_s:.2}s -> {ratio:.2}x \
         | vs all singles combined {sum_s:.2}s -> {ratio_vs_sum:.2}x"
    );
    println!("auto stage attribution: {auto_stages}");

    // --- JSON artifact -----------------------------------------------------
    let mut json = String::from("[\n");
    for r in &rows {
        json.push_str(&format!(
            "  {{\"kernel\": \"{}\", \"baseline_ms\": {:.4}, \"optimized_ms\": {:.4}, \
             \"speedup\": {:.3}}},\n",
            r.kernel,
            r.baseline_ms,
            r.optimized_ms,
            r.speedup()
        ));
    }
    json.push_str(&format!(
        "  {{\"kernel\": \"planner_auto\", \"auto_s\": {auto_s:.4}, \
         \"best_single\": \"{best_name}\", \"best_single_s\": {best_s:.4}, \
         \"ratio\": {ratio:.3}}},\n"
    ));
    json.push_str(&format!(
        "  {{\"kernel\": \"planner_auto_vs_sum\", \"auto_s\": {auto_s:.4}, \
         \"singles_sum_s\": {sum_s:.4}, \"ratio\": {ratio_vs_sum:.3}}},\n"
    ));
    json.push_str(&format!("  {stage_json}\n]\n"));
    std::fs::write(&out_path, &json).expect("write bench json");
    println!("wrote {out_path}");

    if strict {
        let get = |k: &str| rows.iter().find(|r| r.kernel == k).unwrap().speedup();
        assert!(get("guarantee") >= 2.0, "guarantee < 2x: {}", get("guarantee"));
        assert!(
            get("huffman_decode") >= 3.0,
            "huffman decode < 3x: {}",
            get("huffman_decode")
        );
        assert!(ratio <= 1.2, "auto {ratio:.2}x > 1.2x of best single-codec");
    }
}
