//! Partial-decode benchmark: full archive decode vs a single-species,
//! single-time-window `decompress_range`, with the archive bytes each path
//! touches.  Runs on the pure-Rust reference backend, so no AOT artifacts
//! are needed:
//!
//! ```bash
//! cargo bench --bench perf_partial_decode
//! GBATC_BENCH_PROFILE=small GBATC_KT_WINDOW=4 cargo bench --bench perf_partial_decode
//! ```

use gbatc::archive::{CountingSource, SectionSource, SliceSource};
use gbatc::compressor::{CompressOptions, GbatcCompressor};
use gbatc::data::{generate, Profile};
use gbatc::runtime::{ExecService, RuntimeSpec};
use gbatc::util::Timer;

fn main() {
    let profile = std::env::var("GBATC_BENCH_PROFILE")
        .ok()
        .and_then(|p| Profile::parse(&p))
        .unwrap_or(Profile::Tiny);
    let kt_window: usize = std::env::var("GBATC_KT_WINDOW")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4);
    let reps: usize = std::env::var("GBATC_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);

    eprintln!("[bench] generating {profile:?} dataset...");
    let ds = generate(profile, 99);
    let service = ExecService::start_reference(RuntimeSpec::reference_default(), 4)
        .expect("reference service");
    let handle = service.handle();
    let comp = GbatcCompressor::new(&handle, 0, 0);

    let opts = CompressOptions {
        nrmse_target: 1e-3,
        kt_window,
        ..Default::default()
    };
    let t = Timer::start();
    let report = comp.compress(&ds, &opts).expect("compress");
    eprintln!(
        "[bench] compressed {}x{}x{}x{} into {} shards in {:.1}s ({} B archive, peak workspace {:.1} MB)",
        ds.nt,
        ds.ns,
        ds.ny,
        ds.nx,
        report.n_shards,
        t.secs(),
        report.archive.payload_bytes(),
        report.peak_workspace_bytes as f64 / 1e6
    );
    let archive = report.archive;

    println!(
        "== perf_partial_decode ({}x{}x{}x{}, {} shards, kt_window {})",
        ds.nt, ds.ns, ds.ny, ds.nx, report.n_shards, archive.header.kt_window
    );

    // full decode
    let mut full_s = f64::INFINITY;
    for _ in 0..reps {
        let t = Timer::start();
        let full = comp.decompress(&archive, 0).expect("full decode");
        full_s = full_s.min(t.secs());
        assert_eq!(full.len(), ds.mass.len());
    }
    println!(
        "full decode      {:>8.3} ms   {:>10} B read",
        full_s * 1e3,
        archive.bytes.len()
    );

    // one species, one shard window
    let w = archive.header.kt_window.min(ds.nt);
    let species = [ds.ns / 2];
    let mut part_s = f64::INFINITY;
    let mut bytes_read = 0u64;
    for _ in 0..reps {
        let src = SliceSource(&archive.bytes);
        let counting = CountingSource::new(&src);
        let t = Timer::start();
        let out = comp
            .extract(&counting, 0, w, &species, 0)
            .expect("partial decode");
        part_s = part_s.min(t.secs());
        bytes_read = counting.bytes_read();
        assert_eq!(out.mass.len(), w * ds.ny * ds.nx);
        let _ = counting.source_len();
    }
    println!(
        "1 species x 1 win {:>7.3} ms   {:>10} B read",
        part_s * 1e3,
        bytes_read
    );
    println!(
        "speedup {:.1}x | IO reduction {:.1}x",
        full_s / part_s.max(1e-12),
        archive.bytes.len() as f64 / bytes_read.max(1) as f64
    );
}
