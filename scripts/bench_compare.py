#!/usr/bin/env python3
"""Threshold gate for the hot-path kernel bench.

Compares a fresh ``BENCH_hotpaths.json`` (written by
``cargo bench --bench perf_hotpaths``) against the committed baseline and
fails on a >TOLERANCE relative regression.  Only *machine-relative*
metrics are gated — per-kernel speedups (baseline kernel vs optimized
kernel timed on the same machine in the same process), wall-time ratios
(planner auto/best-single, serve traced/untraced), and hit rates — so
the gate is meaningful on any runner; absolute milliseconds (including
the serve bench's server-side p50/p99) are reported but never compared.

Gating is by key: ``speedup`` and ``hit_rate`` are floors (current may
not fall more than the tolerance below baseline), ``ratio`` is a cap
(current may not exceed baseline by more than the tolerance).  A
baseline row may carry its own ``tolerance`` field to override the
global one — the serve trace-overhead row uses 0.02 so that tracing
costing more than ~2% throughput fails the gate.  Rows with none of the
gated keys, and extra keys like ``note``, are informational only.

Usage:
    python3 scripts/bench_compare.py CURRENT.json BASELINE.json [--tolerance 0.25]
"""

import argparse
import json
import sys


def load_rows(path):
    with open(path) as f:
        rows = json.load(f)
    return {row["kernel"]: row for row in rows}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="freshly measured BENCH_hotpaths.json")
    ap.add_argument("baseline", help="committed baseline json")
    ap.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed relative regression (default 0.25 = 25%%)",
    )
    args = ap.parse_args()

    current = load_rows(args.current)
    baseline = load_rows(args.baseline)
    failures = []

    print(f"{'kernel':<20} {'metric':<8} {'baseline':>10} {'current':>10} {'floor/cap':>10}")
    for kernel, base in baseline.items():
        cur = current.get(kernel)
        if cur is None:
            failures.append(f"{kernel}: missing from current results")
            continue
        tol = base.get("tolerance", args.tolerance)
        if "speedup" in base:
            floor = base["speedup"] * (1.0 - tol)
            got = cur.get("speedup", 0.0)
            print(f"{kernel:<20} {'speedup':<8} {base['speedup']:>10.2f} {got:>10.2f} {floor:>10.2f}")
            if got < floor:
                failures.append(
                    f"{kernel}: speedup {got:.2f}x fell below floor {floor:.2f}x "
                    f"(baseline {base['speedup']:.2f}x - {tol:.0%})"
                )
        elif "ratio" in base:
            cap = base["ratio"] * (1.0 + tol)
            got = cur.get("ratio", float("inf"))
            print(f"{kernel:<20} {'ratio':<8} {base['ratio']:>10.2f} {got:>10.2f} {cap:>10.2f}")
            if got > cap:
                failures.append(
                    f"{kernel}: ratio {got:.2f}x exceeded cap {cap:.2f}x "
                    f"(baseline {base['ratio']:.2f}x + {tol:.0%})"
                )
        elif "hit_rate" in base:
            # hit rates are already machine-relative (a property of the
            # query mix, not the runner); gate with the same floor rule
            floor = base["hit_rate"] * (1.0 - tol)
            got = cur.get("hit_rate", 0.0)
            print(f"{kernel:<20} {'hit_rate':<8} {base['hit_rate']:>10.2f} {got:>10.2f} {floor:>10.2f}")
            if got < floor:
                failures.append(
                    f"{kernel}: hit_rate {got:.2f} fell below floor {floor:.2f} "
                    f"(baseline {base['hit_rate']:.2f} - {tol:.0%})"
                )
        # rows without speedup/ratio/hit_rate (e.g. stage_times) are informational

    if failures:
        print("\nREGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nall hot-path metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
