"""Build-time training loops for the GBATC autoencoder and TCN (Adam + MSE)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model


def _batches(n: int, bs: int, rng: np.random.Generator):
    idx = rng.permutation(n)
    for i in range(0, n - bs + 1, bs):
        yield idx[i:i + bs]


def train_ae(blocks: np.ndarray, steps: int = 400, bs: int = 128,
             lr: float = 2e-3, seed: int = 0, log_every: int = 50):
    """blocks: [Nb, S, 4, 5, 4] normalized f32. Returns (params, loss_log)."""
    params = model.init_ae(jax.random.PRNGKey(seed))
    opt = model.adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(p, o, xb):
        loss, g = jax.value_and_grad(model.ae_loss)(p, xb)
        p, o = model.adam_update(p, g, o, lr=lr)
        return p, o, loss

    log, done, t0 = [], 0, time.time()
    while done < steps:
        for idx in _batches(blocks.shape[0], bs, rng):
            xb = jnp.asarray(blocks[idx])
            params, opt, loss = step(params, opt, xb)
            done += 1
            if done % log_every == 0 or done == steps:
                log.append((done, float(loss)))
                print(f"[train_ae] step {done:5d} loss {float(loss):.3e} "
                      f"({time.time() - t0:.0f}s)", flush=True)
            if done >= steps:
                break
    return params, log


def train_tcn(recon: np.ndarray, orig: np.ndarray, steps: int = 400,
              bs: int = 8192, lr: float = 1e-3, seed: int = 1,
              log_every: int = 50):
    """recon/orig: [P, S] point-wise species vectors (normalized)."""
    params = model.init_tcn(jax.random.PRNGKey(seed))
    opt = model.adam_init(params)
    rng = np.random.default_rng(seed)

    @jax.jit
    def step(p, o, rb, ob):
        loss, g = jax.value_and_grad(model.tcn_loss)(p, rb, ob)
        p, o = model.adam_update(p, g, o, lr=lr)
        return p, o, loss

    log, done, t0 = [], 0, time.time()
    while done < steps:
        for idx in _batches(recon.shape[0], bs, rng):
            rb, ob = jnp.asarray(recon[idx]), jnp.asarray(orig[idx])
            params, opt, loss = step(params, opt, rb, ob)
            done += 1
            if done % log_every == 0 or done == steps:
                log.append((done, float(loss)))
                print(f"[train_tcn] step {done:5d} loss {float(loss):.3e} "
                      f"({time.time() - t0:.0f}s)", flush=True)
            if done >= steps:
                break
    return params, log
