"""L2 JAX model: GBATC autoencoder + tensor correction network.

Architecture follows the paper exactly (Fig. 1 / Fig. 3 / §III):
  * AE encoder: two Conv3D layers (58 species as channels, LeakyReLU) over a
    58 x 4 x 5 x 4 spatiotemporal block, then ONE fully-connected layer to a
    latent of size 36 ("additional fc layers do not enhance compression
    accuracy for this application").
  * AE decoder: mirror — FC from latent, reshape, two Conv3DTranspose layers.
  * TCN: point-wise overcomplete MLP 58 -> 232 -> 464 -> 232 -> 58 with
    LeakyReLU, mapping reconstructed species tensors back toward the
    originals.  We parameterize it residually (output = input + net(input)),
    which is the same function class and trains much faster; see DESIGN.md.

Backend switch: the *exported* HLO routes every dense layer through the L1
Pallas kernel (with export-sized tiles so the grid stays small); *training*
uses the pure-jnp/lax oracle ops, which pytest proves numerically identical
to the kernels (interpret-mode Pallas inside a training loop is ~100x slower
to no numerical benefit).  Call `use_pallas(True)` before lowering.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul_bias_act  # noqa: F401 (pallas FC path)
from .kernels.ref import matmul_bias_act_ref, conv3d_ref

Params = Dict[str, jax.Array]

_USE_PALLAS = False
# export-time tile sizes: large tiles -> small sequential grid in the
# lowered while-loop, still ~3*512^2*4B = 3 MiB VMEM per tile set.
_TILE = dict(bm=8192, bn=512, bk=512)


def use_pallas(on: bool) -> None:
    """Route dense layers through the Pallas kernel (export) or oracle (train)."""
    global _USE_PALLAS
    _USE_PALLAS = on


def _mm(x, w, b, act):
    if _USE_PALLAS:
        from .kernels.matmul import matmul_bias_act_pallas
        return matmul_bias_act_pallas(x, w, b, act=act, alpha=ALPHA, **_TILE)
    return matmul_bias_act_ref(x, w, b, act=act, alpha=ALPHA)


def _conv(x, w, b, act):
    # Convs always lower through lax.conv (XLA's fused, multithreaded conv):
    # interpret-mode Pallas wraps the grid in a sequential HLO while-loop,
    # which measured ~300x slower on the CPU PJRT backend for the im2col
    # matmuls (EXPERIMENTS.md §Perf L2-1).  The Pallas im2col conv remains
    # in kernels/conv.py with its own correctness tests.
    return conv3d_ref(x, w, b, act=act, alpha=ALPHA)


def _conv_t(x, w, b, act):
    # stride-1 SAME transposed conv == conv with flipped, IO-swapped weights
    wt = jnp.flip(w, axis=(2, 3, 4)).transpose(1, 0, 2, 3, 4)
    return conv3d_ref(x, wt, b, act=act, alpha=ALPHA)

S = 58                 # species (conv channels)
BLOCK = (4, 5, 4)      # K timesteps, BY, BX — paper's block shape
LATENT = 36            # paper's latent size
C1, C2 = 32, 16        # conv channel widths
FLAT = C2 * BLOCK[0] * BLOCK[1] * BLOCK[2]  # 16*4*5*4 = 1280
TCN_WIDTHS = (S, 232, 464, 232, S)  # paper's §III TCN layer sizes
ALPHA = 0.01           # LeakyReLU slope


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.float32(
        math.sqrt(2.0 / fan_in)
    )


def init_ae(key: jax.Array) -> Params:
    k = jax.random.split(key, 8)
    kd, kh, kw = 3, 3, 3
    return {
        # encoder
        "e_conv1_w": _he(k[0], (C1, S, kd, kh, kw), S * 27),
        "e_conv1_b": jnp.zeros((C1,), jnp.float32),
        "e_conv2_w": _he(k[1], (C2, C1, kd, kh, kw), C1 * 27),
        "e_conv2_b": jnp.zeros((C2,), jnp.float32),
        "e_fc_w": _he(k[2], (FLAT, LATENT), FLAT),
        "e_fc_b": jnp.zeros((LATENT,), jnp.float32),
        # decoder
        "d_fc_w": _he(k[3], (LATENT, FLAT), LATENT),
        "d_fc_b": jnp.zeros((FLAT,), jnp.float32),
        "d_conv1_w": _he(k[4], (C2, C1, kd, kh, kw), C2 * 27),
        "d_conv1_b": jnp.zeros((C1,), jnp.float32),
        "d_conv2_w": _he(k[5], (C1, S, kd, kh, kw), C1 * 27),
        "d_conv2_b": jnp.zeros((S,), jnp.float32),
    }


def init_tcn(key: jax.Array) -> Params:
    p: Params = {}
    keys = jax.random.split(key, len(TCN_WIDTHS) - 1)
    for i, (a, b) in enumerate(zip(TCN_WIDTHS[:-1], TCN_WIDTHS[1:])):
        p[f"t{i}_w"] = _he(keys[i], (a, b), a)
        p[f"t{i}_b"] = jnp.zeros((b,), jnp.float32)
    # scale the last layer down so the residual branch starts near identity
    p[f"t{len(TCN_WIDTHS) - 2}_w"] = p[f"t{len(TCN_WIDTHS) - 2}_w"] * 0.01
    return p


def encode(p: Params, x: jax.Array) -> jax.Array:
    """x [B, S, 4, 5, 4] -> latent [B, LATENT]."""
    h = _conv(x, p["e_conv1_w"], p["e_conv1_b"], "leaky_relu")
    h = _conv(h, p["e_conv2_w"], p["e_conv2_b"], "leaky_relu")
    h = h.reshape(h.shape[0], FLAT)
    return _mm(h, p["e_fc_w"], p["e_fc_b"], "none")


def decode(p: Params, z: jax.Array) -> jax.Array:
    """latent [B, LATENT] -> x^R [B, S, 4, 5, 4]."""
    h = _mm(z, p["d_fc_w"], p["d_fc_b"], "leaky_relu")
    h = h.reshape(h.shape[0], C2, *BLOCK)
    h = _conv_t(h, p["d_conv1_w"], p["d_conv1_b"], "leaky_relu")
    return _conv_t(h, p["d_conv2_w"], p["d_conv2_b"], "none")


def autoencode(p: Params, x: jax.Array) -> jax.Array:
    return decode(p, encode(p, x))


def tcn_apply(p: Params, v: jax.Array) -> jax.Array:
    """Point-wise correction of species vectors, v [P, S] -> [P, S]."""
    h = v
    n = len(TCN_WIDTHS) - 1
    for i in range(n):
        act = "leaky_relu" if i < n - 1 else "none"
        h = _mm(h, p[f"t{i}_w"], p[f"t{i}_b"], act)
    return v + h


def ae_loss(p: Params, x: jax.Array) -> jax.Array:
    r = autoencode(p, x)
    return jnp.mean((x - r) ** 2)


def tcn_loss(p: Params, recon: jax.Array, orig: jax.Array) -> jax.Array:
    return jnp.mean((tcn_apply(p, recon) - orig) ** 2)


def param_count(p: Params) -> int:
    return int(sum(v.size for v in p.values()))


# ---------------------------------------------------------------------------
# Adam (no optax in this image — five lines of math, build-time only)
# ---------------------------------------------------------------------------

def adam_init(p: Params):
    zeros = {k: jnp.zeros_like(v) for k, v in p.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in p.items()},
            "t": jnp.zeros((), jnp.float32)}


def adam_update(p: Params, g: Params, st, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = st["t"] + 1.0
    m = {k: b1 * st["m"][k] + (1 - b1) * g[k] for k in p}
    v = {k: b2 * st["v"][k] + (1 - b2) * g[k] ** 2 for k in p}
    mh = {k: m[k] / (1 - b1 ** t) for k in p}
    vh = {k: v[k] / (1 - b2 ** t) for k in p}
    newp = {k: p[k] - lr * mh[k] / (jnp.sqrt(vh[k]) + eps) for k in p}
    return newp, {"m": m, "v": v, "t": t}
