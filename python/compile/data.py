"""Synthetic S3D-HCCI-like dataset generator (build-time substitute).

The paper evaluates on Sandia S3D DNS output: compression ignition of a lean
n-heptane/air mixture (Yoo et al. 58-species reduced mechanism), a 640x640
2D domain sampled over 50 timesteps in t = 1.5..2.0 ms.  That dataset (and
S3D itself) is not available here, so we synthesize a field with the same
statistical structure the compressor exploits (see DESIGN.md §3):

* a base isentropic-compression temperature ramp plus advected
  Gaussian-random-field temperature inhomogeneities (few-mode turbulence),
* a two-stage ignition progress variable (low-T ignition c1, high-T
  ignition c2) whose local delay depends on the temperature fluctuation —
  producing intermittent ignition fronts,
* 58 species mass fractions that are species-specific nonlinear functions of
  (c1, c2, T) spanning ~8 decades of magnitude, so that all species live on
  a shared low-dimensional manifold (the paper measures linear-PCA rank
  46/58) while majors and minors behave differently,
* weak correlated multiplicative noise so the manifold is not exactly
  low-rank.

The Rust crate ports the same formulas (rust/src/data/synth.rs) so examples
and benches can generate data without python; both sides are deterministic
given a seed, but only the python output is used for AE training.
"""

from __future__ import annotations

import numpy as np

# Species table — order is the cross-language ABI (rust/src/chem/species.rs
# mirrors it).  Names follow the Yoo et al. 58-species n-heptane skeletal
# mechanism flavor; roles drive the synthetic manifold functions below.
#   role: fuel | oxidizer | inert | product | co | intermediate | radical | lowT
SPECIES = [
    # name,            role,           magnitude, stage-center, width
    ("nC7H16",         "fuel",         2.5e-02, 0.00, 0.30),
    ("O2",             "oxidizer",     2.2e-01, 0.00, 0.40),
    ("N2",             "inert",        7.2e-01, 0.00, 1.00),
    ("CO2",            "product",      8.0e-02, 0.95, 0.30),
    ("H2O",            "product",      6.5e-02, 0.90, 0.30),
    ("CO",             "co",           4.5e-02, 0.55, 0.22),
    ("H2",             "co",           1.5e-03, 0.50, 0.25),
    ("H",              "radical",      3.0e-05, 0.80, 0.12),
    ("O",              "radical",      8.0e-05, 0.78, 0.12),
    ("OH",             "radical",      2.5e-03, 0.82, 0.15),
    ("HO2",            "radical",      1.2e-04, 0.45, 0.18),
    ("H2O2",           "intermediate", 3.0e-04, 0.40, 0.16),
    ("CH3",            "radical",      2.0e-04, 0.55, 0.15),
    ("CH4",            "intermediate", 9.0e-04, 0.50, 0.22),
    ("CH2O",           "intermediate", 1.8e-03, 0.42, 0.16),
    ("HCO",            "radical",      6.0e-06, 0.60, 0.12),
    ("CH3O",           "radical",      2.0e-06, 0.48, 0.12),
    ("C2H2",           "intermediate", 4.0e-04, 0.62, 0.15),
    ("C2H3",           "radical",      5.0e-06, 0.60, 0.11),
    ("C2H4",           "intermediate", 3.5e-03, 0.52, 0.18),
    ("C2H5",           "radical",      4.0e-06, 0.45, 0.12),
    ("C2H6",           "intermediate", 4.0e-04, 0.40, 0.18),
    ("CH2CHO",         "radical",      3.0e-06, 0.55, 0.11),
    ("CH3CHO",         "intermediate", 2.5e-04, 0.38, 0.15),
    ("C3H4",           "intermediate", 8.0e-05, 0.55, 0.14),
    ("C3H5",           "radical",      6.0e-05, 0.52, 0.13),
    ("C3H6",           "intermediate", 1.5e-03, 0.45, 0.16),
    ("nC3H7",          "radical",      2.0e-06, 0.30, 0.10),
    ("C4H7",           "radical",      4.0e-06, 0.35, 0.11),
    ("C4H8-1",         "intermediate", 6.0e-04, 0.38, 0.14),
    ("pC4H9",          "radical",      1.5e-06, 0.28, 0.10),
    ("C5H9",           "radical",      2.5e-06, 0.33, 0.10),
    ("C5H10-1",        "intermediate", 3.5e-04, 0.35, 0.13),
    ("C6H12-1",        "intermediate", 2.5e-04, 0.32, 0.12),
    ("C7H15-2",        "radical",      3.0e-06, 0.20, 0.09),
    ("C7H15O2",        "lowT",         5.0e-05, 0.15, 0.10),
    ("C7H14OOH",       "lowT",         1.2e-05, 0.16, 0.09),
    ("OC7H13OOH",      "lowT",         4.0e-06, 0.18, 0.09),
    ("nC7KET12",       "lowT",         2.0e-05, 0.17, 0.09),
    ("C5H11CO",        "lowT",         1.5e-06, 0.22, 0.09),
    ("nC3H7COCH2",     "lowT",         8.0e-07, 0.20, 0.08),
    ("CH3COCH2",       "radical",      2.0e-06, 0.42, 0.11),
    ("CH3COCH3",       "intermediate", 8.0e-05, 0.35, 0.13),
    ("C2H5CHO",        "intermediate", 4.0e-05, 0.30, 0.12),
    ("C2H5CO",         "radical",      8.0e-07, 0.32, 0.10),
    ("CH3OCH3",        "intermediate", 2.0e-05, 0.33, 0.12),
    ("CH3OCH2",        "radical",      5.0e-07, 0.36, 0.10),
    ("HOCH2O",         "lowT",         3.0e-06, 0.25, 0.10),
    ("HCOOH",          "intermediate", 5.0e-05, 0.38, 0.13),
    ("CH3O2",          "lowT",         8.0e-06, 0.22, 0.10),
    ("CH3O2H",         "lowT",         6.0e-06, 0.24, 0.10),
    ("C2H3CHO",        "intermediate", 6.0e-05, 0.48, 0.13),
    ("C2H3CO",         "radical",      4.0e-07, 0.50, 0.10),
    ("aC3H5CHO",       "intermediate", 1.5e-05, 0.44, 0.12),
    ("NO",             "product",      1.2e-04, 0.97, 0.25),
    ("NO2",            "intermediate", 1.5e-05, 0.70, 0.18),
    ("N2O",            "intermediate", 8.0e-06, 0.75, 0.18),
    ("NNH",            "radical",      2.0e-08, 0.85, 0.12),
]
assert len(SPECIES) == 58
S = 58

PROFILES = {
    # name: (T, Y, X)
    "tiny":   (8, 40, 40),
    "small":  (16, 80, 80),
    "medium": (24, 320, 320),
    "paper":  (48, 640, 640),
}

N_MODES = 12  # Fourier modes in the turbulence / inhomogeneity fields


def _mode_params(rng: np.random.Generator):
    """Random low-wavenumber Fourier modes: (kx, ky, phase, amp, ux, uy)."""
    kx = rng.integers(1, 9, size=N_MODES).astype(np.float32)
    ky = rng.integers(1, 9, size=N_MODES).astype(np.float32)
    ph = rng.uniform(0.0, 2.0 * np.pi, size=N_MODES).astype(np.float32)
    amp = (rng.uniform(0.4, 1.0, size=N_MODES) / np.sqrt(kx**2 + ky**2)).astype(np.float32)
    amp /= np.sum(amp)
    ux = rng.uniform(-0.15, 0.15, size=N_MODES).astype(np.float32)
    uy = rng.uniform(-0.15, 0.15, size=N_MODES).astype(np.float32)
    return kx, ky, ph, amp, ux, uy


def generate(profile: str = "small", seed: int = 7):
    """Return (Y[T,S,Y,X] float32 mass fractions, Temp[T,Y,X] float32 K)."""
    nt, ny, nx = PROFILES[profile]
    rng = np.random.default_rng(seed)

    xs = np.linspace(0.0, 1.0, nx, endpoint=False, dtype=np.float32)
    ys = np.linspace(0.0, 1.0, ny, endpoint=False, dtype=np.float32)
    gx, gy = np.meshgrid(xs, ys, indexing="xy")  # [ny, nx]
    tt = np.linspace(0.0, 1.0, nt, dtype=np.float32)  # normalized t in [1.5, 2.0] ms

    kx, ky, ph, amp, ux, uy = _mode_params(rng)
    kx2, ky2, ph2, amp2, ux2, uy2 = _mode_params(rng)
    kx3, ky3, ph3, amp3, ux3, uy3 = _mode_params(rng)

    def grf(t, kxs, kys, phs, amps, uxs, uys):
        """Advected Gaussian-random-field-like sum of Fourier modes."""
        f = np.zeros((ny, nx), dtype=np.float32)
        for m in range(N_MODES):
            f += amps[m] * np.sin(
                2.0 * np.pi * (kxs[m] * (gx - uxs[m] * t) + kys[m] * (gy - uys[m] * t))
                + phs[m]
            )
        return f

    mass = np.empty((nt, S, ny, nx), dtype=np.float32)
    temp = np.empty((nt, ny, nx), dtype=np.float32)

    mag = np.array([sp[2] for sp in SPECIES], dtype=np.float32)
    ctr = np.array([sp[3] for sp in SPECIES], dtype=np.float32)
    wid = np.array([sp[4] for sp in SPECIES], dtype=np.float32)
    roles = [sp[1] for sp in SPECIES]

    for it, t in enumerate(tt):
        theta = grf(t, kx, ky, ph, amp, ux, uy)  # temperature inhomogeneity
        # local two-stage ignition delays modulated by theta (hotter -> earlier)
        d1 = 0.18 - 0.22 * theta  # low-T stage (mostly before the window)
        d2 = 0.55 - 0.35 * theta  # high-T stage (inside the window)
        c1 = 1.0 / (1.0 + np.exp(-(t - d1) / 0.035))
        c2 = 1.0 / (1.0 + np.exp(-(t - d2) / 0.045))
        # base compression ramp + heat release of both stages
        tbase = 1050.0 + 120.0 * t
        T = tbase + 55.0 * theta + 140.0 * c1 + 950.0 * c2
        temp[it] = T.astype(np.float32)

        # shared progress coordinate for the species manifold
        c = 0.25 * c1 + 0.75 * c2
        # weak correlated multiplicative noise (keeps rank high)
        eps1 = grf(t, kx2, ky2, ph2, amp2, ux2, uy2)
        eps2 = grf(t, kx3, ky3, ph3, amp3, ux3, uy3)

        tn = (T - 1050.0) / 1200.0  # normalized temperature
        for k in range(S):
            role = roles[k]
            if role == "fuel":
                f = (1.0 - c1) * (1.0 - 0.92 * c2)
            elif role == "oxidizer":
                f = 1.0 - 0.55 * c2 - 0.05 * c1
            elif role == "inert":
                f = np.full_like(c, 1.0) + 0.0008 * eps1
            elif role == "product":
                g = 1.0 / (1.0 + np.exp(-(c - ctr[k]) / (0.25 * wid[k] + 0.05)))
                f = g * (1.0 + 0.05 * tn)
            elif role == "co":
                f = np.exp(-((c - ctr[k]) ** 2) / (2.0 * wid[k] ** 2)) * (0.25 + 0.75 * c2) \
                    + 0.15 * c2
            elif role == "lowT":
                # low-T ignition species: keyed to stage 1, consumed by stage 2
                f = np.exp(-((0.25 * c1 + 0.02 - ctr[k]) ** 2) / (2.0 * wid[k] ** 2)) \
                    * c1 * (1.0 - c2) ** 2
            else:  # intermediate | radical: bump along the shared coordinate
                f = np.exp(-((c - ctr[k]) ** 2) / (2.0 * wid[k] ** 2))
                if role == "radical":
                    # radicals additionally Arrhenius-amplified by temperature
                    f = f * np.exp(2.2 * (tn - 0.5))
            noise = 1.0 + 0.004 * eps1 + 0.0024 * eps2 * np.float32(np.sin(3.1 * k + 0.7))
            mass[it, k] = (mag[k] * f * noise).astype(np.float32)

    np.clip(mass, 0.0, None, out=mass)
    return mass, temp


def write_dataset(path: str, mass: np.ndarray, temp: np.ndarray) -> None:
    """SDF1 container: magic, dims, temperature[T,Y,X], mass[T,S,Y,X] (LE f32)."""
    nt, s, ny, nx = mass.shape
    with open(path, "wb") as f:
        f.write(b"SDF1")
        np.array([nt, s, ny, nx], dtype="<u4").tofile(f)
        temp.astype("<f4").tofile(f)
        mass.astype("<f4").tofile(f)


def read_dataset(path: str):
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == b"SDF1", f"bad magic {magic!r}"
        nt, s, ny, nx = np.fromfile(f, dtype="<u4", count=4)
        temp = np.fromfile(f, dtype="<f4", count=nt * ny * nx).reshape(nt, ny, nx)
        mass = np.fromfile(f, dtype="<f4", count=nt * s * ny * nx).reshape(nt, s, ny, nx)
    return mass, temp


def blockify(mass: np.ndarray, kt: int = 4, by: int = 5, bx: int = 4) -> np.ndarray:
    """[T,S,Y,X] -> [Nb, S, kt, by, bx] non-overlapping spatiotemporal blocks."""
    nt, s, ny, nx = mass.shape
    assert nt % kt == 0 and ny % by == 0 and nx % bx == 0
    m = mass.reshape(nt // kt, kt, s, ny // by, by, nx // bx, bx)
    m = m.transpose(0, 3, 5, 2, 1, 4, 6)  # [Tb, Yb, Xb, S, kt, by, bx]
    return np.ascontiguousarray(m.reshape(-1, s, kt, by, bx))


def deblockify(blocks: np.ndarray, nt: int, ny: int, nx: int,
               kt: int = 4, by: int = 5, bx: int = 4) -> np.ndarray:
    """Inverse of blockify."""
    s = blocks.shape[1]
    m = blocks.reshape(nt // kt, ny // by, nx // bx, s, kt, by, bx)
    m = m.transpose(0, 4, 3, 1, 5, 2, 6)  # [Tb, kt, S, Yb, by, Xb, bx]
    return np.ascontiguousarray(m.reshape(nt, s, ny, nx))


def species_ranges(mass: np.ndarray):
    """Per-species (min, max) over the full field — the NRMSE normalizer."""
    lo = mass.min(axis=(0, 2, 3))
    hi = mass.max(axis=(0, 2, 3))
    return lo.astype(np.float32), hi.astype(np.float32)


def normalize(mass: np.ndarray, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    rng = np.maximum(hi - lo, 1e-30)
    return ((mass - lo[None, :, None, None]) / rng[None, :, None, None]).astype(np.float32)
