"""AOT entrypoint: train GBATC on the synthetic S3D-like dataset and export
HLO-text artifacts for the rust runtime.

Run once at build time (`make artifacts`); the rust binary is self-contained
afterwards.  Outputs in --out-dir:

  dataset.bin       — SDF1 container (temperature + 58-species mass fractions)
  encoder.hlo.txt   — [B, 58, 4, 5, 4] normalized blocks -> [B, 36] latents
  decoder.hlo.txt   — [B, 36] -> [B, 58, 4, 5, 4]
  tcn.hlo.txt       — [P, 58] point species vectors -> corrected [P, 58]
  manifest.txt      — shapes, batch sizes, parameter counts (CR accounting)
  train_log.txt     — AE/TCN loss curves (EXPERIMENTS.md provenance)

HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 (the `xla` crate's backend)
rejects; the text parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_hlo(fn, specs, path: str) -> None:
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)", flush=True)


def write_params_sidecar(params: dict, path: str) -> list:
    """Write trained parameters as a binary sidecar (`GBPR` format).

    HLO *text* elides large constants (`constant({...})`), so weights cannot
    be baked into the artifact; instead the exported computation takes them
    as runtime arguments, in sorted-key order, and the rust runtime feeds
    them from this sidecar on every execution.  Returns the sorted keys.
    """
    keys = sorted(params.keys())
    with open(path, "wb") as f:
        f.write(b"GBPR")
        np.array([len(keys)], dtype="<u4").tofile(f)
        for k in keys:
            name = k.encode()
            np.array([len(name)], dtype="<u4").tofile(f)
            f.write(name)
            arr = np.asarray(params[k], dtype=np.float32)
            np.array([arr.ndim], dtype="<u4").tofile(f)
            np.array(arr.shape, dtype="<u4").tofile(f)
            arr.astype("<f4").tofile(f)
    print(f"[aot] wrote {path} ({len(keys)} tensors)", flush=True)
    return keys


def export_model_hlo(apply_fn, params: dict, x_spec, hlo_path: str,
                     params_path: str) -> None:
    """Export `apply_fn(params, x)` with params as trailing arguments."""
    keys = write_params_sidecar(params, params_path)

    def wrapped(x, plist):
        p = dict(zip(keys, plist))
        return (apply_fn(p, x),)

    plist_specs = [
        jax.ShapeDtypeStruct(np.asarray(params[k]).shape, jnp.float32)
        for k in keys
    ]
    export_hlo(wrapped, [x_spec, plist_specs], hlo_path)


def reconstruct_all(params, blocks: np.ndarray, bs: int) -> np.ndarray:
    """AE reconstruction of every block, batched (build-time helper)."""
    fn = jax.jit(lambda x: model.autoencode(params, x))
    out = np.empty_like(blocks)
    n = blocks.shape[0]
    for i in range(0, n, bs):
        j = min(i + bs, n)
        xb = blocks[i:j]
        pad = bs - (j - i)
        if pad:
            xb = np.concatenate([xb, np.zeros((pad, *xb.shape[1:]), xb.dtype)])
        out[i:j] = np.asarray(fn(jnp.asarray(xb)))[: j - i]
    return out


def blocks_to_points(blocks: np.ndarray) -> np.ndarray:
    """[Nb, S, kt, by, bx] -> [Nb*kt*by*bx, S] species vectors."""
    nb, s = blocks.shape[:2]
    return np.ascontiguousarray(
        blocks.transpose(0, 2, 3, 4, 1).reshape(-1, s)
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", default="small", choices=list(D.PROFILES))
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--ae-steps", type=int, default=int(os.environ.get("GBATC_AE_STEPS", 350)))
    ap.add_argument("--tcn-steps", type=int, default=int(os.environ.get("GBATC_TCN_STEPS", 300)))
    ap.add_argument("--batch", type=int, default=256, help="encoder/decoder HLO batch")
    ap.add_argument("--points", type=int, default=8192, help="TCN HLO point batch")
    ap.add_argument("--reuse-checkpoint", action="store_true",
                    help="skip training if artifacts/checkpoint.npz exists")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    t0 = time.time()

    # 1. dataset ------------------------------------------------------------
    print(f"[aot] generating profile={args.profile} seed={args.seed}", flush=True)
    mass, temp = D.generate(args.profile, args.seed)
    D.write_dataset(os.path.join(args.out_dir, "dataset.bin"), mass, temp)

    lo, hi = D.species_ranges(mass)
    norm = D.normalize(mass, lo, hi)
    blocks = D.blockify(norm)
    print(f"[aot] {blocks.shape[0]} blocks of shape {blocks.shape[1:]}", flush=True)

    # 2. train (or reuse a cached checkpoint for export-only iterations) ----
    ckpt = os.path.join(args.out_dir, "checkpoint.npz")
    if args.reuse_checkpoint and os.path.exists(ckpt):
        print(f"[aot] reusing {ckpt}", flush=True)
        z = np.load(ckpt)
        ae_params = {k[3:]: jnp.asarray(z[k]) for k in z.files if k.startswith(("ae_e", "ae_d"))}
        tcn_params = {k[4:]: jnp.asarray(z[k]) for k in z.files if k.startswith("tcn_t")}
        ae_log = [(0, float(z["ae_loss"]))]
        tcn_log = [(0, float(z["tcn_loss"]))]
    else:
        ae_params, ae_log = train.train_ae(blocks, steps=args.ae_steps, seed=args.seed)
        recon = reconstruct_all(ae_params, blocks, args.batch)
        tcn_params, tcn_log = train.train_tcn(
            blocks_to_points(recon), blocks_to_points(blocks),
            steps=args.tcn_steps, seed=args.seed + 1)
        np.savez(
            ckpt,
            ae_loss=ae_log[-1][1],
            tcn_loss=tcn_log[-1][1],
            **{f"ae_{k}": np.asarray(v) for k, v in ae_params.items()},
            **{f"tcn_{k}": np.asarray(v) for k, v in tcn_params.items()},
        )

    with open(os.path.join(args.out_dir, "train_log.txt"), "w") as f:
        for step, loss in ae_log:
            f.write(f"ae {step} {loss:.6e}\n")
        for step, loss in tcn_log:
            f.write(f"tcn {step} {loss:.6e}\n")

    # 3. export HLO — dense layers through the L1 Pallas kernel; weights as
    # runtime arguments + GBPR sidecars (HLO text elides large constants)
    model.use_pallas(True)
    bshape = (args.batch, model.S, *model.BLOCK)
    enc_params = {k: v for k, v in ae_params.items() if k.startswith("e_")}
    dec_params = {k: v for k, v in ae_params.items() if k.startswith("d_")}
    export_model_hlo(model.encode, enc_params,
                     jax.ShapeDtypeStruct(bshape, jnp.float32),
                     os.path.join(args.out_dir, "encoder.hlo.txt"),
                     os.path.join(args.out_dir, "encoder.params"))
    export_model_hlo(model.decode, dec_params,
                     jax.ShapeDtypeStruct((args.batch, model.LATENT), jnp.float32),
                     os.path.join(args.out_dir, "decoder.hlo.txt"),
                     os.path.join(args.out_dir, "decoder.params"))
    export_model_hlo(model.tcn_apply, tcn_params,
                     jax.ShapeDtypeStruct((args.points, model.S), jnp.float32),
                     os.path.join(args.out_dir, "tcn.hlo.txt"),
                     os.path.join(args.out_dir, "tcn.params"))

    # 4. manifest -----------------------------------------------------------
    enc_n = sum(v.size for k, v in ae_params.items() if k.startswith("e_"))
    dec_n = sum(v.size for k, v in ae_params.items() if k.startswith("d_"))
    tcn_n = model.param_count(tcn_params)
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(f"species={model.S}\n")
        f.write(f"block_t={model.BLOCK[0]}\nblock_y={model.BLOCK[1]}\nblock_x={model.BLOCK[2]}\n")
        f.write(f"latent={model.LATENT}\n")
        f.write(f"encoder_batch={args.batch}\n")
        f.write(f"tcn_points={args.points}\n")
        f.write(f"encoder_params={enc_n}\n")
        f.write(f"decoder_params={dec_n}\n")
        f.write(f"tcn_params={tcn_n}\n")
        f.write(f"train_profile={args.profile}\n")
        f.write(f"seed={args.seed}\n")
        f.write(f"ae_final_loss={ae_log[-1][1]:.6e}\n")
        f.write(f"tcn_final_loss={tcn_log[-1][1]:.6e}\n")
    print(f"[aot] done in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
