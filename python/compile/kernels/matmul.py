"""L1 Pallas kernel: fused tiled matmul + bias + LeakyReLU.

This is the compute hot-spot of GBATC: every fully-connected layer — the AE
bottleneck FC, the decoder FC, and all four layers of the tensor-correction
network (which runs point-wise over *every* grid point and dominates
decompression FLOPs) — routes through this kernel.  The 3D convolutions also
route through it via im2col (see kernels/conv.py), so essentially all model
FLOPs execute here.

TPU-style design (see DESIGN.md §4/§8):
  * grid (M/bm, N/bn, K/bk), k-innermost so the f32 accumulator tile stays
    resident in VMEM while A/B tiles stream HBM->VMEM;
  * bias add + LeakyReLU fused into the k==last epilogue — no second HBM
    round-trip for the activation;
  * tile sizes default to 128x128x128: 3 * 128*128*4 B ≈ 192 KiB << VMEM,
    and 128 lanes match the MXU systolic array.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and in interpret mode the kernel still traces to plain HLO so
the exported artifact runs anywhere.

Training differentiates through this kernel via a custom VJP whose backward
pass reuses the same Pallas kernel for both dX and dW.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(v: int, b: int) -> int:
    return -(-v // b) * b


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, alpha: float,
                   fuse_bias: bool, act: str):
    """One (i, j, k) grid step: o += x_tile @ w_tile; epilogue on last k.

    The output tile doubles as the f32 accumulator (all GBATC tensors are
    f32), so no scratch buffer is needed and the tile stays VMEM-resident
    across the k loop.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if fuse_bias:
            acc = acc + b_ref[...]
        if act == "leaky_relu":
            acc = jnp.where(acc >= 0.0, acc, alpha * acc)
        elif act == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def matmul_bias_act_pallas(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    act: str = "none",
    alpha: float = 0.01,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jax.Array:
    """act(x @ w + b) with act in {none, relu, leaky_relu}; f32 accumulate."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} != {k2}"
    fuse_bias = b is not None
    if fuse_bias:
        assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm, bn, bk = min(bm, _round_up(m, 8)), min(bn, _round_up(n, 8)), min(bk, _round_up(k, 8))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n)) if fuse_bias else jnp.zeros((np_,), x.dtype)

    nk = kp // bk
    grid = (mp // bm, np_ // bn, nk)

    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, nk=nk, alpha=alpha, fuse_bias=fuse_bias, act=act
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n].astype(x.dtype)


# ---------------------------------------------------------------------------
# Differentiable wrapper: custom VJP whose backward pass reuses the kernel.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def matmul_bias_act(x, w, b, act="none", alpha=0.01):
    """Differentiable fused act(x @ w + b) running on the Pallas kernel."""
    return matmul_bias_act_pallas(x, w, b, act=act, alpha=alpha)


def _fwd(x, w, b, act, alpha):
    pre = matmul_bias_act_pallas(x, w, b, act="none")
    if act == "leaky_relu":
        y = jnp.where(pre >= 0.0, pre, alpha * pre)
    elif act == "relu":
        y = jnp.maximum(pre, 0.0)
    else:
        y = pre
    return y, (x, w, pre)


def _bwd(act, alpha, res, g):
    x, w, pre = res
    if act == "leaky_relu":
        g = jnp.where(pre >= 0.0, g, alpha * g)
    elif act == "relu":
        g = jnp.where(pre >= 0.0, g, 0.0)
    dx = matmul_bias_act_pallas(g, w.T, None, act="none")
    dw = matmul_bias_act_pallas(x.T, g, None, act="none")
    db = jnp.sum(g, axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_fwd, _bwd)
