"""3D convolution routed through the Pallas matmul kernel via im2col.

The AE's Conv3D / Conv3DTranspose layers (Fig. 1 of the paper) are stride-1
SAME convolutions over the tiny 4x5x4 block extent, so a transposed
convolution is exactly a convolution with spatially-flipped, IO-swapped
weights — both directions use `conv3d` here.  im2col turns the convolution
into one [B*D*H*W, C*27] x [C*27, O] matmul, which is executed by the L1
Pallas kernel, keeping all model FLOPs on the hot kernel.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .matmul import matmul_bias_act


def _im2col3d(x: jax.Array, kd: int, kh: int, kw: int) -> jax.Array:
    """[B,C,D,H,W] -> [B*D*H*W, C*kd*kh*kw] patches (SAME, stride 1)."""
    b, c, d, h, w = x.shape
    pd, ph, pw = kd // 2, kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (pd, pd), (ph, ph), (pw, pw)))
    cols = []
    for dz in range(kd):
        for dy in range(kh):
            for dx in range(kw):
                cols.append(xp[:, :, dz:dz + d, dy:dy + h, dx:dx + w])
    # [kd*kh*kw, B, C, D, H, W] -> [B, D, H, W, C, kd*kh*kw]
    pat = jnp.stack(cols, axis=0).transpose(1, 3, 4, 5, 2, 0)
    return pat.reshape(b * d * h * w, c * kd * kh * kw)


def conv3d(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
           *, act: str = "none", alpha: float = 0.01) -> jax.Array:
    """SAME stride-1 conv, x [B,C,D,H,W], w [O,I,kd,kh,kw] -> [B,O,D,H,W]."""
    bsz, c, d, h, wd = x.shape
    o, i, kd, kh, kw = w.shape
    assert i == c, f"in-channels {i} != {c}"
    cols = _im2col3d(x, kd, kh, kw)  # [B*D*H*W, C*k3]
    # weight as [C*k3, O] with matching (C, kd, kh, kw) ordering
    wm = w.transpose(1, 2, 3, 4, 0).reshape(c * kd * kh * kw, o)
    y = matmul_bias_act(cols, wm, b if b is not None else jnp.zeros((o,), x.dtype),
                        act, alpha)
    return y.reshape(bsz, d, h, wd, o).transpose(0, 4, 1, 2, 3)


def conv3d_transpose(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
                     *, act: str = "none", alpha: float = 0.01) -> jax.Array:
    """Stride-1 SAME transposed conv == conv with flipped, IO-swapped kernel.

    x [B,O,D,H,W], w [O,I,kd,kh,kw] (the forward-conv weight) -> [B,I,D,H,W].
    """
    wt = jnp.flip(w, axis=(2, 3, 4)).transpose(1, 0, 2, 3, 4)  # [I,O,kd,kh,kw]
    return conv3d(x, wt, b, act=act, alpha=alpha)
