"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has an exact reference here; pytest
(python/tests/test_kernels.py) sweeps shapes with hypothesis and asserts
allclose between kernel and oracle.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(
    x: jax.Array,
    w: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    act: str = "none",
    alpha: float = 0.01,
) -> jax.Array:
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    if act == "leaky_relu":
        y = jnp.where(y >= 0.0, y, alpha * y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def conv3d_ref(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
               *, act: str = "none", alpha: float = 0.01) -> jax.Array:
    """SAME-padded stride-1 3D convolution, NCDHW / OIDHW layout."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1, 1), padding="SAME",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    )
    if b is not None:
        y = y + b[None, :, None, None, None]
    if act == "leaky_relu":
        y = jnp.where(y >= 0.0, y, alpha * y)
    elif act == "relu":
        y = jnp.maximum(y, 0.0)
    return y
