"""L1 Pallas kernels for GBATC (build-time only; exported into HLO)."""

from .matmul import matmul_bias_act, matmul_bias_act_pallas  # noqa: F401
from .conv import conv3d, conv3d_transpose  # noqa: F401
from . import ref  # noqa: F401
