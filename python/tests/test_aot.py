"""AOT export tests: HLO text round-trips and matches the jax model."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def _lower(fn, specs):
    return jax.jit(fn).lower(*specs)


def test_hlo_text_nonempty_and_parseable_header():
    p = model.init_ae(jax.random.PRNGKey(0))
    model.use_pallas(True)
    try:
        low = _lower(
            lambda x: (model.encode(p, x),),
            [jax.ShapeDtypeStruct((8, model.S, *model.BLOCK), jnp.float32)],
        )
        text = aot.to_hlo_text(low)
    finally:
        model.use_pallas(False)
    assert len(text) > 1000
    assert text.lstrip().startswith("HloModule")
    # 32-bit-safe ids requirement: text parser reassigns, but sanity check
    assert "f32[8,58,4,5,4]" in text.replace(" ", "")


def test_exported_graph_matches_eager_model():
    """Compile the exported HLO path via jax and compare numerics."""
    p = model.init_ae(jax.random.PRNGKey(1))
    x = jnp.asarray(
        np.random.default_rng(0).random((4, model.S, *model.BLOCK), dtype=np.float32)
    )
    model.use_pallas(True)
    try:
        z_exported = jax.jit(lambda x: model.encode(p, x))(x)
    finally:
        model.use_pallas(False)
    z_eager = model.encode(p, x)
    np.testing.assert_allclose(
        np.asarray(z_exported), np.asarray(z_eager), rtol=2e-5, atol=2e-5
    )


def test_blocks_to_points_ordering():
    blocks = np.arange(2 * 3 * 4 * 5 * 4, dtype=np.float32).reshape(2, 3, 4, 5, 4)
    pts = aot.blocks_to_points(blocks)
    assert pts.shape == (2 * 4 * 5 * 4, 3)
    # point 0 of block 0 = (species 0..2 at t0,y0,x0)
    np.testing.assert_array_equal(pts[0], blocks[0, :, 0, 0, 0])
    np.testing.assert_array_equal(pts[1], blocks[0, :, 0, 0, 1])


def test_reconstruct_all_pads_tail_batch():
    p = model.init_ae(jax.random.PRNGKey(2))
    blocks = np.random.default_rng(3).random(
        (5, model.S, *model.BLOCK)
    ).astype(np.float32)
    out = aot.reconstruct_all(p, blocks, bs=4)  # 5 = 4 + 1 (padded tail)
    ref = np.asarray(model.autoencode(p, jnp.asarray(blocks)))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
