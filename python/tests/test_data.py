"""Synthetic dataset generator tests (python side; rust mirrors these)."""

import numpy as np
import pytest

from compile import data as D


def test_shapes_and_determinism():
    m1, t1 = D.generate("tiny", 7)
    m2, t2 = D.generate("tiny", 7)
    assert m1.shape == (8, 58, 40, 40)
    assert t1.shape == (8, 40, 40)
    np.testing.assert_array_equal(m1, m2)
    m3, _ = D.generate("tiny", 8)
    assert not np.array_equal(m1, m3)


def test_physicality():
    mass, temp = D.generate("tiny", 7)
    assert np.all(mass >= 0) and np.all(np.isfinite(mass))
    assert np.all(temp > 900) and np.all(temp < 3000)
    fuel = mass[:, 0].mean(axis=(1, 2))
    h2o = mass[:, 4].mean(axis=(1, 2))
    assert fuel[-1] < fuel[0]  # fuel consumed
    assert h2o[-1] > h2o[0]  # product formed


def test_blockify_roundtrip():
    mass, _ = D.generate("tiny", 7)
    blocks = D.blockify(mass)
    assert blocks.shape == (2 * 8 * 10, 58, 4, 5, 4)
    back = D.deblockify(blocks, mass.shape[0], mass.shape[2], mass.shape[3])
    np.testing.assert_array_equal(back, mass)


def test_normalize_ranges():
    mass, _ = D.generate("tiny", 7)
    lo, hi = D.species_ranges(mass)
    norm = D.normalize(mass, lo, hi)
    assert norm.min() >= -1e-6 and norm.max() <= 1 + 1e-6
    # every species actually spans [0, 1]
    assert np.all(norm.max(axis=(0, 2, 3)) > 0.99)


def test_dataset_io_roundtrip(tmp_path):
    mass, temp = D.generate("tiny", 9)
    p = str(tmp_path / "ds.bin")
    D.write_dataset(p, mass, temp)
    m2, t2 = D.read_dataset(p)
    np.testing.assert_array_equal(mass, m2)
    np.testing.assert_array_equal(temp, t2)


def test_species_magnitudes_span_decades():
    mags = np.array([s[2] for s in D.SPECIES])
    assert mags.max() / mags.min() > 1e6


def test_blockify_rejects_bad_dims():
    mass = np.zeros((5, 58, 40, 40), dtype=np.float32)  # 5 % 4 != 0
    with pytest.raises(AssertionError):
        D.blockify(mass)
