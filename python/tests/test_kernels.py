"""L1 kernel correctness: Pallas vs pure-jnp oracle (hypothesis shape sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_bias_act, matmul_bias_act_pallas, conv3d, \
    conv3d_transpose
from compile.kernels.ref import matmul_bias_act_ref, conv3d_ref


def _rand(key, shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32,
                              -1.0, 1.0)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    act=st.sampled_from(["none", "relu", "leaky_relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = matmul_bias_act_pallas(x, w, b, act=act)
    want = matmul_bias_act_ref(x, w, b, act=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (128, 128, 128), (129, 127, 130),
                                   (256, 58, 232), (80, 1280, 36)])
def test_matmul_model_shapes(m, k, n):
    x, w, b = _rand(0, (m, k)), _rand(1, (k, n)), _rand(2, (n,))
    got = matmul_bias_act_pallas(x, w, b, act="leaky_relu")
    want = matmul_bias_act_ref(x, w, b, act="leaky_relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_matmul_no_bias():
    x, w = _rand(3, (33, 17)), _rand(4, (17, 9))
    got = matmul_bias_act_pallas(x, w, None)
    want = matmul_bias_act_ref(x, w, None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_matmul_custom_blocks():
    x, w, b = _rand(5, (100, 70)), _rand(6, (70, 40)), _rand(7, (40,))
    got = matmul_bias_act_pallas(x, w, b, act="leaky_relu", bm=32, bn=16, bk=8)
    want = matmul_bias_act_ref(x, w, b, act="leaky_relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_matmul_grads_match_ref():
    """Custom VJP (pallas bwd) equals autodiff through the jnp oracle."""
    x, w, b = _rand(8, (24, 12)), _rand(9, (12, 7)), _rand(10, (7,))

    def f_ker(x, w, b):
        return jnp.sum(matmul_bias_act(x, w, b, "leaky_relu") ** 2)

    def f_ref(x, w, b):
        return jnp.sum(matmul_bias_act_ref(x, w, b, act="leaky_relu") ** 2)

    gk = jax.grad(f_ker, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-4, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 4),
    c=st.integers(1, 8),
    o=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv3d_matches_lax(b, c, o, seed):
    x = _rand(seed, (b, c, 4, 5, 4))
    w = _rand(seed + 1, (o, c, 3, 3, 3))
    bias = _rand(seed + 2, (o,))
    got = conv3d(x, w, bias, act="leaky_relu")
    want = conv3d_ref(x, w, bias, act="leaky_relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_conv3d_transpose_adjointness():
    """<conv(x), y> == <x, conv_T(y)> — the defining transpose property."""
    x = _rand(11, (2, 3, 4, 5, 4))
    w = _rand(12, (6, 3, 3, 3, 3))
    y = _rand(13, (2, 6, 4, 5, 4))
    cx = conv3d(x, w)
    cty = conv3d_transpose(y, w)
    lhs = float(jnp.sum(cx * y))
    rhs = float(jnp.sum(x * cty))
    assert abs(lhs - rhs) < 1e-2 * max(1.0, abs(lhs))
