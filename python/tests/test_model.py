"""L2 model tests: shapes, training step sanity, backend equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def _blocks(n=4, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random((n, model.S, *model.BLOCK), dtype=np.float32))


def test_encoder_decoder_shapes():
    p = model.init_ae(jax.random.PRNGKey(0))
    x = _blocks(3)
    z = model.encode(p, x)
    assert z.shape == (3, model.LATENT)
    r = model.decode(p, z)
    assert r.shape == (3, model.S, *model.BLOCK)


def test_tcn_shape_and_near_identity_at_init():
    p = model.init_tcn(jax.random.PRNGKey(1))
    v = jnp.asarray(np.random.default_rng(1).random((16, model.S), dtype=np.float32))
    out = model.tcn_apply(p, v)
    assert out.shape == v.shape
    # residual parameterization with downscaled last layer: near-identity
    assert float(jnp.max(jnp.abs(out - v))) < 0.5


def test_ae_loss_decreases_with_training():
    from compile import train

    rng = np.random.default_rng(2)
    # structured blocks (low-rank across species) so learning is possible
    base = rng.random((1, 1, *model.BLOCK), dtype=np.float32)
    scales = rng.random((64, model.S, 1, 1, 1), dtype=np.float32)
    blocks = (base * scales).astype(np.float32)
    params, log = train.train_ae(blocks, steps=60, bs=32, lr=3e-3, seed=0,
                                 log_every=30)
    assert log[-1][1] < log[0][1], f"loss did not decrease: {log}"


def test_tcn_widths_match_paper():
    assert model.TCN_WIDTHS == (58, 232, 464, 232, 58)
    assert model.LATENT == 36
    assert model.BLOCK == (4, 5, 4)


def test_pallas_and_oracle_backends_agree():
    """The exported (pallas) graph must equal the trained (oracle) graph."""
    p = model.init_ae(jax.random.PRNGKey(3))
    tp = model.init_tcn(jax.random.PRNGKey(4))
    x = _blocks(2, seed=5)
    v = jnp.asarray(np.random.default_rng(6).random((32, model.S), dtype=np.float32))
    try:
        model.use_pallas(False)
        z_ref = model.encode(p, x)
        r_ref = model.decode(p, z_ref)
        t_ref = model.tcn_apply(tp, v)
        model.use_pallas(True)
        z_pl = model.encode(p, x)
        r_pl = model.decode(p, z_pl)
        t_pl = model.tcn_apply(tp, v)
    finally:
        model.use_pallas(False)
    np.testing.assert_allclose(np.asarray(z_ref), np.asarray(z_pl), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(r_ref), np.asarray(r_pl), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(t_ref), np.asarray(t_pl), rtol=2e-5, atol=2e-5)


def test_adam_moves_toward_minimum():
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = model.adam_init(p)
    for _ in range(400):
        g = {"w": 2.0 * p["w"]}  # grad of ||w||^2
        p, st = model.adam_update(p, g, st, lr=0.05)
    assert float(jnp.max(jnp.abs(p["w"]))) < 0.3
